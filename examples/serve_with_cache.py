"""END-TO-END DRIVER: serve a small LLM with batched requests behind a
semantic cache — the paper's deployment, wired through every layer of
this framework (embedder fine-tune -> cache -> vector store -> serving
engine -> decoder backbone).

    PYTHONPATH=src python examples/serve_with_cache.py \
        --arch granite-moe-3b-a800m --queries 120 --batch 8

Any assigned decoder arch works via --arch (reduced variant on CPU).
Prints the hit/miss trace and the cost accounting the paper's Figure 4
motivates (LLM forward passes saved by the cache).

By default the serving path runs the tiered multi-tenant CacheService
(hot exact tier + warm IVF tier, demotion, admission, response GC);
pass --flat for the paper's bare SemanticCache, --tenants N to
round-robin batches over N isolated logical caches,
--background-rebuild to double-buffer the warm IVF re-cluster off the
hot path (DESIGN.md §7), --learned-admission to refit per-tenant
thresholds/margins online from observed duplicate rates (DESIGN.md
§9), --learned-embedder to fine-tune the embedder itself from pooled
serving feedback and hot-swap it with a versioned shadow re-embed
(DESIGN.md §11), --cold-capacity N to back the warm ring with a
host-RAM cold tier that catches demotions and serves them back through
budgeted fetches + async promotion (DESIGN.md §12).  For serving
several embedders at once through the fused multi-embedder cascade
with learned per-tenant mixture weights, see ``repro.launch.serve
--ensemble E`` (DESIGN.md §13).  Requests flow
through the typed plan/commit
lifecycle (near-identical misses in a batch share one generation) and
the summary prints the protocol's unified stats() snapshot.
"""
import argparse
import time

import jax
import numpy as np

from repro.cache_service import (
    CacheConfig, CacheService, EmbedderRefreshPolicy, LearningConfig,
    TieringConfig,
)
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import EmbedderTrainer, FinetuneConfig, SemanticCache
from repro.data import HashTokenizer, make_pair_dataset, make_query_stream
from repro.models import init_lm, split
from repro.obs import Telemetry, write_jsonl
from repro.serving import CachedLLMService, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m",
                    choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--queries", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--threshold", type=float, default=0.93)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--no-finetune", action="store_true")
    ap.add_argument("--flat", action="store_true",
                    help="use the paper's flat SemanticCache instead of "
                         "the tiered CacheService")
    ap.add_argument("--tenants", type=int, default=1,
                    help="round-robin request batches over N logical "
                         "tenants (tiered cache only)")
    ap.add_argument("--fused", action="store_true",
                    help="run the cascade through the fused Pallas "
                         "lookup kernel (TPU; four-op fallback on CPU)")
    ap.add_argument("--background-rebuild", action="store_true",
                    help="double-buffer the warm IVF rebuild: k-means "
                         "runs on a shadow index off the hot path and "
                         "maintenance() publishes it between batches")
    ap.add_argument("--learned-admission", action="store_true",
                    help="learn per-tenant thresholds and admission "
                         "margins online from observed duplicate rates "
                         "(maintenance() refits them under hysteresis "
                         "guards, DESIGN.md §9)")
    ap.add_argument("--learned-embedder", action="store_true",
                    help="fine-tune the compact embedder online from "
                         "pooled serving feedback; maintenance() trains "
                         "in the background, gates on held-out eval, and "
                         "hot-swaps with a versioned shadow re-embed "
                         "(DESIGN.md §11)")
    ap.add_argument("--cold-capacity", type=int, default=0,
                    help="host-RAM cold-tier rows behind the warm ring: "
                         "warm evictions demote instead of dropping, "
                         "below-threshold queries fall through to a "
                         "budgeted cold fetch, maintenance() promotes "
                         "re-hot rows back (0 = off; DESIGN.md §12)")
    ap.add_argument("--warm-block", type=int, default=0,
                    help="stream the fused kernel's warm panel in "
                         "N-row blocks (0 = whole-panel; DESIGN.md §12)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the telemetry registry snapshot as "
                         "JSON-lines after the run (DESIGN.md §10.1); "
                         "validate with python -m repro.obs.export "
                         "--validate PATH")
    args = ap.parse_args()
    if args.flat and (args.fused or args.background_rebuild
                      or args.learned_admission or args.learned_embedder
                      or args.cold_capacity or args.warm_block):
        ap.error("--fused/--background-rebuild/--learned-admission/"
                 "--learned-embedder/--cold-capacity/--warm-block "
                 "require the tiered CacheService (drop --flat)")

    # --- LLM backend (reduced variant of the assigned arch) -----------
    dec_cfg = get_config(args.arch).reduced()
    print(f"backend: {dec_cfg.name} ({dec_cfg.param_count():,} params)")
    pv, _ = split(init_lm(dec_cfg, jax.random.PRNGKey(0)))
    engine = ServeEngine(dec_cfg, pv, max_len=64)

    # --- cache-side embedder (paper recipe) ---------------------------
    enc_cfg = get_config("modernbert-149m").reduced(vocab_size=4096)
    tok = HashTokenizer(vocab_size=enc_cfg.vocab_size)
    trainer = EmbedderTrainer(enc_cfg, FinetuneConfig(
        epochs=2, batch_size=32, lr=5e-4, max_len=24, margin=0.7))
    if not args.no_finetune:
        print("fine-tuning embedder (online contrastive, clip 0.5)...")
        trainer.fit(make_pair_dataset("medical", 1024, seed=0), tok)

    telemetry = Telemetry()
    if args.flat:
        cache = SemanticCache(capacity=4096, dim=enc_cfg.d_model,
                              threshold=args.threshold,
                              telemetry=telemetry)
    else:
        # smoke-scale refresh policy: trip inside a short stream, with
        # grammar backfill when the pooled pairs run thin (§11)
        refresh = EmbedderRefreshPolicy(
            min_pairs=24, min_class=4, refresh_interval=32,
            synth_domain="medical", synth_min_pairs=128,
            recalibrate=True,
        ) if args.learned_embedder else None
        cache = CacheService(CacheConfig(
            dim=enc_cfg.d_model, threshold=args.threshold,
            admission_margin=0.02, telemetry=telemetry,
            tiering=TieringConfig(
                hot_capacity=512, warm_capacity=4096, n_clusters=32,
                bucket=256, n_probe=4, flush_size=128, fused=args.fused,
                background_rebuild=args.background_rebuild,
                cold_capacity=args.cold_capacity,
                warm_block=args.warm_block or None),
            learning=LearningConfig(
                learned_admission=args.learned_admission,
                learned_embedder=args.learned_embedder,
                embedder_trainer=trainer
                if args.learned_embedder else None,
                embedder_tokenizer=tok
                if args.learned_embedder else None,
                refresh_policy=refresh)))
        print(f"cascade path: {'fused kernel' if cache.fused else 'four-op'}"
              f" (backend {jax.default_backend()})")
    svc = CachedLLMService(trainer.make_embed_fn(tok), cache, engine, tok,
                           max_new_tokens=args.max_new_tokens)

    # --- batched serving loop over a repeated-query trace -------------
    stream = make_query_stream("medical", args.queries, seed=11,
                               repeat_frac=0.4)
    texts = [q.text for q in stream]
    t0 = time.perf_counter()
    llm_time = 0.0
    for i in range(0, len(texts), args.batch):
        batch = texts[i:i + args.batch]
        tenant = (i // args.batch) % max(args.tenants, 1)
        t1 = time.perf_counter()
        results = svc.handle(batch, tenant=tenant) if not args.flat \
            else svc.handle(batch)
        dt = time.perf_counter() - t1
        n_hit = sum(r.cache_hit for r in results)
        if i // args.batch < 5:
            for r in results[:2]:
                tag = "HIT " if r.cache_hit else "MISS"
                print(f"  [{tag}] {r.query[:60]!r}")
        print(f"batch {i//args.batch:3d}: {n_hit}/{len(batch)} hits "
              f"({dt*1e3:.0f} ms)")
    total = time.perf_counter() - t0

    # one unified snapshot: serving counters at the top level, the
    # backend's stats_snapshot() sections nested under "backend" (the
    # flat stats() view was removed in v2.0)
    st = svc.stats()
    bk = st["backend"]
    print(f"\n=== serving summary ===")
    print(f"queries: {args.queries}  batches of {args.batch}")
    print(f"cache hits: {st['hits']}  misses: {st['misses']}  "
          f"hit rate: {st['hit_rate']:.1%}")
    print(f"LLM generations: {st['generations']} "
          f"(coalesced duplicate misses: {st['coalesced_misses']})")
    print(f"LLM forward passes saved: {st['hits']} "
          f"({st['hits'] * args.max_new_tokens} decode steps)")
    print(f"wall time: {total:.1f}s  cache occupancy: {cache.occupancy:.1%}")
    if not args.flat:
        print(f"tiers: hot hits {bk['traffic']['hot_hits']}  warm hits "
              f"{bk['traffic']['warm_hits']}  demotions "
              f"{bk['tiers']['demotions']}  "
              f"rebuilds {bk['rebuild']['rebuilds']} "
              f"(background: {bk['rebuild']['shadow_started']}, last "
              f"{bk['rebuild']['last_wall_s'] * 1e3:.0f} ms, total "
              f"{bk['rebuild']['total_wall_s'] * 1e3:.0f} ms)")
        print(f"admission skips: {bk['admission']['skipped']}  "
              f"responses GC'd: {bk['tiers']['evictions']}  live: "
              f"{bk['tiers']['live_responses']}")
        if args.cold_capacity:
            cd = cache.stats_snapshot().tiers["cold"]
            print(f"cold tier: {cd['cold_rows']} rows "
                  f"({cd['cold_occupancy']:.0%}), hits {cd['cold_hits']} "
                  f"from {cd['cold_fetches']} fetches "
                  f"({cd['cold_fetched_rows']} rows shipped, "
                  f"{cd['cold_router_skips']} router skips); promoted "
                  f"{cd['cold_promoted']}, final drops "
                  f"{cd['cold_dropped']}")
        if args.learned_admission:
            lrn = bk["learning"]
            print(f"learned admission: {lrn['refits_applied']} refits "
                  f"from {lrn['feedback_events']} events "
                  f"({lrn['duplicate_events']} duplicates, "
                  f"{lrn['wasted_admissions']} wasted admissions)")
            for t, pol in lrn["learned_policies"].items():
                print(f"  tenant {t}: threshold "
                      f"{pol['threshold']:.3f}  margin "
                      f"{pol['admission_margin']:.3f}")
        if args.learned_embedder:
            cache.maintenance(block=True)   # join an in-flight refresh
            st = svc.stats()
            rf = st["backend"]["refresh"]
            print(f"learned embedder: version {rf['embed_version']} "
                  f"({rf['refreshes_published']} published, "
                  f"{rf['refreshes_rolled_back']} rolled back from "
                  f"{rf['refreshes_started']} started; "
                  f"{rf['pairs_held']} pairs pooled, "
                  f"{rf['stale_version_commits']} stale-version "
                  f"commits)")

    # --- telemetry: stage breakdown + SLO health (DESIGN.md §10) ------
    cache.maintenance(block=True)     # final idle tick: drain SLO gauges
    print("\n=== telemetry (DESIGN.md §10) ===")
    print(f"maintenance calls between batches: {st['maintenance_calls']}")
    stage_h = telemetry.stage_histogram()
    for stage in ("embed", "plan", "cold_fetch", "generate", "commit",
                  "maintenance"):
        agg = stage_h.aggregate(stage=stage)
        if agg.count:
            print(f"  stage {stage:<12} p50 {agg.quantile(0.5) * 1e3:7.2f} "
                  f"ms  mean {agg.mean * 1e3:7.2f} ms  x{agg.count}")
    root = telemetry.tracer.last_root()
    if root is not None:
        print(f"last request span tree: {root.name} "
              f"({root.duration_s * 1e3:.1f} ms) -> "
              f"{' -> '.join(root.stage_names())}")
    if telemetry.health is not None and not args.flat:
        hs = telemetry.health.snapshot()
        for t, s in hs["tenants"].items():
            print(f"  tenant {t}: hit ewma {s['hit']['ewma']:.2f}  "
                  f"dup-admission {s['wasted_admission']['windowed']:.3f}  "
                  f"budget burn {s['budget_burn']:.2f}")
        reb = hs["rebuild"]
        if reb["publishes"]:
            print(f"  rebuild overlap: {reb['overlap_plans_total']} plans "
                  f"during shadow builds, publish stall p99 "
                  f"{reb['stall_p99_s'] * 1e3:.2f} ms")
    if args.metrics_json:
        write_jsonl(args.metrics_json, telemetry.registry.snapshot(),
                    meta={"arch": dec_cfg.name, "queries": args.queries,
                          "flat": bool(args.flat)})
        print(f"metrics -> {args.metrics_json}")


if __name__ == "__main__":
    main()
