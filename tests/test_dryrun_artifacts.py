"""Validates the recorded dry-run artifacts: every assigned (arch ×
shape) must have compiled on BOTH production meshes (the multi-pod
requirement).  Skips when the sweep output isn't present (fresh clone) —
regenerate with:  python -m repro.launch.dryrun --all --both-meshes
--scan --out results/scan
"""
import glob
import json
import os

import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

scan_files = glob.glob(os.path.join(RESULTS, "scan_*.json"))

pytestmark = pytest.mark.skipif(
    len(scan_files) == 0, reason="dry-run sweep artifacts not present")


def _load_all():
    out = {}
    for p in scan_files:
        with open(p) as f:
            r = json.load(f)
        out[(r["arch"], r["shape"], r["multi_pod"])] = r
    return out


def test_all_80_combinations_compiled():
    arts = _load_all()
    missing = []
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            for mp in (False, True):
                if (arch, shape, mp) not in arts:
                    missing.append((arch, shape, mp))
    assert not missing, f"missing dry-run artifacts: {missing}"


def test_multi_pod_uses_pod_axis():
    arts = _load_all()
    for (arch, shape, mp), r in arts.items():
        if mp:
            assert r["mesh"] == [2, 16, 16]
        else:
            assert r["mesh"] == [16, 16]


def test_memory_analysis_recorded():
    arts = _load_all()
    for key, r in arts.items():
        m = r["memory"]
        assert m["argument_bytes_per_device"] > 0, key
        # per-device argument bytes must be below a v5e chip's 16 GiB
        # for serving shapes (weights+state fully sharded); train temp
        # is CPU-codegen-inflated and judged in §Roofline instead.
        if r["shape"] in ("long_500k",):
            assert m["argument_bytes_per_device"] < 16 * 2**30, key


def test_collective_schedule_present_on_multipod():
    arts = _load_all()
    for (arch, shape, mp), r in arts.items():
        if mp and shape == "train_4k":
            # gradient sync must exist on the multi-pod mesh
            assert r["roofline"]["collective_counts"], (arch, shape)
