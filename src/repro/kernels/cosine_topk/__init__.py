from repro.kernels.cosine_topk.ops import cosine_topk

__all__ = ["cosine_topk"]
