"""Builds the EXPERIMENTS.md §Roofline table from results/roofline_*.json,
the §Dry-run summary from results/scan_*.json, and the tiered-cascade
table (lookup paths + the learned-vs-fixed admission comparison) from
results/BENCH_cascade.json alone:

    python results/make_tables.py cascade
"""
import glob
import json
import sys

def warn(msg):
    print(f"WARNING: {msg}", file=sys.stderr)

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load(prefix):
    out = []
    for p in sorted(glob.glob(f"results/{prefix}_*.json")):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_ms(s):
    return f"{s*1e3:.1f}" if s < 10 else f"{s*1e3:.0f}"


def roofline_table(rows):
    rows = sorted(rows, key=lambda r: (r["arch"],
                                       SHAPE_ORDER.get(r["shape"], 9)))
    print("| arch | shape | prog | t_comp ms | t_mem ms | t_coll ms | "
          "bottleneck | MODEL/HLO | coll GB/dev | args GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        t = r["roofline"]
        shape = r["shape"] + ("*" if r.get("extrapolated") else "")
        print(f"| {r['arch']} | {shape} | {r['program'].split('_')[-1]} "
              f"| {fmt_ms(t['t_compute'])} | {fmt_ms(t['t_memory'])} "
              f"| {fmt_ms(t['t_collective'])} | {t['bottleneck']} "
              f"| {r['useful_flops_ratio']:.2f} "
              f"| {t['per_device_collective_bytes']/1e9:.2f} "
              f"| {r['memory']['argument_bytes_per_device']/2**30:.2f} |")
    print()
    print("(*) train term extrapolated from 1/2-period unrolled lowers "
          "(X(N)=X(1)+(N-1)(X(2)-X(1))); all other cells are full "
          "unrolled compiles.")


def dryrun_table(rows):
    rows = sorted(rows, key=lambda r: (r["arch"],
                                       SHAPE_ORDER.get(r["shape"], 9),
                                       r["multi_pod"]))
    print("| arch | shape | mesh | compile s | args GiB/dev | "
          "coll ops (ar/ag/a2a/cp) |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        c = r["roofline"]["collective_counts"]
        ops = (f"{c.get('all-reduce',0)}/{c.get('all-gather',0)}/"
               f"{c.get('all-to-all',0)}/{c.get('collective-permute',0)}")
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        print(f"| {r['arch']} | {r['shape']} | {mesh} "
              f"| {r['compile_seconds']:.0f} "
              f"| {r['memory']['argument_bytes_per_device']/2**30:.2f} "
              f"| {ops} |")


def cascade_table(path="results/BENCH_cascade.json"):
    """Everything renders from the bench's own JSON: lookup-path rows
    (latency/recall), maintenance/rebuild rows, the per-stage serving
    latency breakdown (DESIGN.md §10), and the learned-vs-fixed
    admission comparison the feedback loop (DESIGN.md §9) is judged
    by, the embedder-refresh comparison (§11), the cold-tier rows
    (§12), and the fused multi-embedder ensemble rows plus the
    learned-vs-uniform mixture-weight comparison (§13).  Every row
    must land in some table; a leftover fails the
    run (a renamed bench row silently falling out of EXPERIMENTS.md is
    exactly how a regression hides)."""
    with open(path) as f:
        data = json.load(f)
    rows = {r["name"]: r for r in data["rows"]}
    rendered = set()
    print(f"Tiered cascade — backend {data['backend']} "
          f"x{data['devices']} device(s), sizes {data['sizes']}, "
          f"Q={data['q']}, threshold {data['threshold']}")
    print()
    print("| row | us/query | p50 ms | recall@thr | speedup vs flat |")
    print("|---|---|---|---|---|")
    for name, r in rows.items():
        if "us_per_query" not in r or name.startswith("tiered/cold/") \
                or name.startswith("tiered/ensemble/"):
            continue      # cold/ensemble rows get their own tables below
        rendered.add(name)
        p50 = f"{r['p50_us']/1e3:.1f}" if "p50_us" in r else "-"
        rec = f"{r['recall_at_thr']:.3f}" if "recall_at_thr" in r else "-"
        spd = f"{r['speedup_vs_flat']:.2f}x" if "speedup_vs_flat" in r \
            else "-"
        print(f"| {name} | {r['us_per_query']:.1f} | {p50} "
              f"| {rec} | {spd} |")

    # maintenance / rebuild rows (DESIGN.md §7): serving-tick latency
    # with the warm rebuild inline vs double-buffered
    reb = [(n, r) for n, r in rows.items()
           if "bg_rebuilds" in r or "flush_size" in r]
    if reb:
        print()
        print("Maintenance (warm flush + IVF rebuild):")
        print()
        print("| row | us/call | tick p50 ms | tick p99 ms | "
              "bg rebuilds |")
        print("|---|---|---|---|---|")
        for name, r in reb:
            rendered.add(name)
            p50 = f"{r['p50_us']/1e3:.1f}" if "p50_us" in r else "-"
            p99 = f"{r['p99_us']/1e3:.1f}" if "p99_us" in r else "-"
            bg = str(r["bg_rebuilds"]) if "bg_rebuilds" in r else "-"
            print(f"| {name} | {r['us_per_call']:.1f} | {p50} "
                  f"| {p99} | {bg} |")

    # per-stage serving latency breakdown (DESIGN.md §10): where a
    # cached tick actually spends its time, from the telemetry
    # registry's stage histogram
    stages = [(n, r) for n, r in rows.items()
              if n.startswith("tiered/serve/stage_")]
    if stages:
        print()
        print("Serving latency breakdown (per stage, from the telemetry "
              "registry, DESIGN.md §10):")
        print()
        print("| stage | p50 us | mean us | ticks |")
        print("|---|---|---|---|")
        for name, r in stages:
            rendered.add(name)
            print(f"| {name.rsplit('stage_', 1)[1]} | {r['p50_us']:.0f} "
                  f"| {r['mean_us']:.0f} | {r['count']} |")
        over = rows.get("tiered/serve/telemetry_overhead")
        if over:
            rendered.add("tiered/serve/telemetry_overhead")
            print()
            print(f"Telemetry overhead: tick p50 {over['p50_on_us']:.0f} "
                  f"us instrumented vs {over['p50_off_us']:.0f} us bare "
                  f"({over['overhead_ratio']:.4f}x, paired-difference "
                  f"estimate {over['median_extra_us']:.0f} us).")

    # fused multi-embedder ensemble (DESIGN.md §13): E key panels in
    # one kernel pass vs the single pilot embedder
    ens = [(n, r) for n, r in rows.items()
           if n.startswith("tiered/ensemble/") and "us_per_query" in r
           and not n.startswith("tiered/ensemble/weights_")]
    if ens:
        print()
        print("Fused multi-embedder ensemble (E key panels, one kernel "
              "pass, DESIGN.md §13):")
        print()
        print("| row | E | us/query | p50 ms | recall@thr | best "
              "single | p50 vs single | speedup vs sequential |")
        print("|---|---|---|---|---|---|---|---|")
        for name, r in ens:
            rendered.add(name)
            best = f"{r['best_single_recall']:.3f}" \
                if "best_single_recall" in r else "-"
            pvs = f"{r['p50_ratio_vs_single']:.2f}x" \
                if "p50_ratio_vs_single" in r else "-"
            spd = f"{r['speedup_vs_sequential']:.2f}x" \
                if "speedup_vs_sequential" in r else "-"
            print(f"| {name} | {r['e']} | {r['us_per_query']:.1f} "
                  f"| {r['p50_us']/1e3:.1f} "
                  f"| {r['recall_at_thr']:.3f} | {best} | {pvs} "
                  f"| {spd} |")

    # per-tenant learned mixture weights vs uniform on the drifting
    # stream (DESIGN.md §13)
    wuni = rows.get("tiered/ensemble/weights_uniform")
    wlrn = rows.get("tiered/ensemble/weights_learned")
    if wuni and wlrn:
        rendered.update(("tiered/ensemble/weights_uniform",
                         "tiered/ensemble/weights_learned"))
        print()
        print("Ensemble mixture weights on the drifting stream (uniform "
              "vs per-tenant learned, same queries, DESIGN.md §13):")
        print()
        print("| weights | dup admissions | admitted | hits | probe "
              "recall | false hits | refits | final weights |")
        print("|---|---|---|---|---|---|---|---|")
        for tag, r in (("uniform", wuni), ("learned", wlrn)):
            wf = "/".join(f"{w:.2f}" for w in r["weights_final"]) \
                if r.get("weights_final") else "-"
            print(f"| {tag} | {r['dup_admissions']} | {r['admitted']} "
                  f"| {r['hits']} | {r['recall_probe']:.3f} "
                  f"| {r['false_hits_probe']} | {r['weight_refits']} "
                  f"| {wf} |")
        drop = 1 - wlrn["dup_admissions"] / max(wuni["dup_admissions"], 1)
        print()
        print(f"Learned mixture weights cut duplicate admissions by "
              f"{drop:.0%} with probe recall "
              f"{wlrn['recall_probe']:.3f} (uniform: "
              f"{wuni['recall_probe']:.3f}).")

    # host-RAM cold tier (DESIGN.md §12): recall past device memory at
    # equal device bytes, plus promotion drain + overhead guard rows
    cold = [(n, r) for n, r in rows.items()
            if n.startswith("tiered/cold/") and "recall_at_thr" in r]
    if cold:
        print()
        print("Cold tier (host-RAM, equal device memory, DESIGN.md §12):")
        print()
        print("| row | corpus | device rows | cold rows | us/query "
              "| recall@thr | cold hit rate | rows fetched | "
              "router skips | fused ens |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for name, r in cold:
            rendered.add(name)
            hr = f"{r['cold_hit_rate']:.2f}" if "cold_hit_rate" in r \
                else "-"
            fetched = str(r.get("cold_fetched_rows", "-"))
            skips = str(r.get("cold_router_skips", "-"))
            print(f"| {name} | {r['n']} | {r['device_rows']} "
                  f"| {r['cold_rows']} | {r['us_per_query']:.1f} "
                  f"| {r['recall_at_thr']:.3f} | {hr} | {fetched} "
                  f"| {skips} | {r.get('ensemble', '-')} |")
        for name, r in rows.items():
            if name.startswith("tiered/cold/") \
                    and name.endswith("/promotion"):
                rendered.add(name)
                print()
                print(f"Promotion drain ({name}): {r['promoted']} rows "
                      f"in {r['wall_us']/1e3:.1f} ms "
                      f"({r['us_per_row']:.0f} us/row) on one "
                      "maintenance tick.")
        ratio = rows.get("tiered/cold/p50_ratio")
        if ratio:
            rendered.add("tiered/cold/p50_ratio")
            print()
            print(f"Cold-path overhead at a warm-feasible size "
                  f"(n={ratio['n']}): serving p50 "
                  f"{ratio['p50_on_us']/1e3:.1f} ms cold-enabled vs "
                  f"{ratio['p50_off_us']/1e3:.1f} ms disabled "
                  f"({ratio['p50_ratio']:.2f}x — the router declines "
                  "the fetches the device already answered).")

    # online embedder refresh (DESIGN.md §11): frozen vs refreshed on
    # the drifted phase, intent-ground-truth scoring
    emb = [(m, rows.get(f"tiered/embedder_{m}"))
           for m in ("frozen", "refreshed")]
    if all(r is not None for _, r in emb):
        rendered.update(f"tiered/embedder_{m}" for m, _ in emb)
        print()
        print("Embedder refresh on the drifting-topic stream (frozen "
              "vs online-refreshed, same queries, DESIGN.md §11):")
        print()
        print("| embedder | hit precision | hit recall | overlap "
              "recall | version | final thr | refresh wall s | "
              "ensemble |")
        print("|---|---|---|---|---|---|---|---|")
        for mode, r in emb:
            print(f"| {mode} | {r['hit_precision']:.3f} "
                  f"| {r['hit_recall']:.3f} | {r['overlap_recall']:.2f} "
                  f"| {r['embed_version']} | {r['threshold_final']} "
                  f"| {r['refresh_wall_s']} "
                  f"| {r.get('ensemble', '-')} |")

    fixed = rows.get("tiered/admission_fixed")
    learned = rows.get("tiered/admission_learned")
    if fixed and learned:
        rendered.update(("tiered/admission_fixed",
                         "tiered/admission_learned"))
        print()
        print("Admission on the drifting stream (fixed rule vs online "
              "learned, same queries):")
        print()
        print("| admission | dup admissions | admitted | hits | "
              "probe recall | false hits | final thr | final margin | "
              "refits |")
        print("|---|---|---|---|---|---|---|---|---|")
        for tag, r in (("fixed", fixed), ("learned", learned)):
            print(f"| {tag} | {r['dup_admissions']} | {r['admitted']} "
                  f"| {r['hits']} | {r['recall_probe']:.3f} "
                  f"| {r['false_hits_probe']} | {r['threshold_final']} "
                  f"| {r['margin_final']} | {r['refits']} |")
        drop = 1 - learned["dup_admissions"] / max(fixed["dup_admissions"],
                                                   1)
        print()
        print(f"Learned admission cuts duplicate admissions by "
              f"{drop:.0%} with probe recall "
              f"{learned['recall_probe']:.3f} (fixed: "
              f"{fixed['recall_probe']:.3f}).")

    # platform-conditional asserts the run skipped (meta, not rows —
    # surfaced so a CPU artifact is never mistaken for accelerator
    # evidence of the latency claims)
    for s in data.get("skipped_asserts", []):
        print()
        print(f"Skipped assert `{s['name']}`: {s['reason']}")

    leftover = sorted(set(rows) - rendered)
    if leftover:
        # a renamed bench row silently falling out of EXPERIMENTS.md is
        # exactly how a regression hides — fail, don't just warn
        warn(f"{len(leftover)} bench row(s) in {path} not rendered by "
             f"any table (renamed or new row?): {', '.join(leftover)}")
        raise SystemExit(1)


def scenarios_table(path="results/BENCH_scenarios.json"):
    """The §14.1 scenario macro-bench table from the bench's own JSON:
    one row per (scenario, mode) replay, the drift learned-vs-conformal
    contrast called out explicitly, and the TTL machinery counters.
    Same leftover discipline as the cascade table: every row must land
    somewhere or the render fails."""
    with open(path) as f:
        data = json.load(f)
    rows = {(r["scenario"], r["mode"]): r for r in data["rows"]}
    rendered = set()
    print(f"Scenario macro-bench — backend {data['backend']} "
          f"x{data['devices']} device(s), dim {data['dim']}, "
          f"seed {data['seed']}"
          + (", SMOKE traces" if data.get("smoke") else "") + ":")
    print()
    print("| scenario | mode | queries | hit rate | false-hit rate "
          "| budget | stale | plan p50 us/row | plan p99 us/row |")
    print("|---|---|---|---|---|---|---|---|---|")
    for key in sorted(rows):
        r = rows[key]
        rendered.add(key)
        print(f"| {r['scenario']} | {r['mode']} | {r['n_queries']} "
              f"| {r['hit_rate']:.3f} | {r['false_hit_rate']:.4f} "
              f"| {r['false_hit_budget']} | {r['stale_serves']} "
              f"| {r['p50_us_per_row']:.0f} "
              f"| {r['p99_us_per_row']:.0f} |")

    fixed = rows.get(("drift", "learned"))
    conf = rows.get(("drift", "conformal"))
    if fixed and conf:
        print()
        print(f"Drift contrast (§14.3): the calibrated-but-fixed "
              f"threshold leaks {fixed['false_hit_rate']:.1%} false "
              f"hits once the negative band drifts over it; the "
              f"per-tenant conformal floor holds "
              f"{conf['false_hit_rate']:.1%} against the "
              f"{conf['false_hit_budget']:.0%} budget on the same "
              f"trace ({conf.get('hit_audits', 0)} served hits "
              f"audited, floors "
              + ", ".join(f"t{t}={v:.3f}" for t, v in
                          sorted(conf.get("conformal_floors",
                                          {}).items()))
              + ").")

    ttl = rows.get(("ttl_churn", "conformal"))
    if ttl:
        print()
        print(f"TTL churn (§14.2): {ttl['ttl_stamped']} inserts "
              f"stamped with a deadline, {ttl['expired_masked']} "
              f"expired rows masked at plan time, "
              f"{ttl['expired_reaped']} reaped by maintenance; "
              f"inside-deadline repeats hit at "
              f"{ttl.get('prewindow_hit_rate', 0):.3f}, "
              f"post-deadline serves: {ttl['stale_serves']} "
              f"(hard-asserted zero).")

    for s in data.get("skipped_asserts", []):
        print()
        print(f"Skipped assert `{s['name']}`: {s['reason']}")

    leftover = sorted(set(rows) - rendered)
    if leftover:
        warn(f"{len(leftover)} scenario row(s) in {path} not rendered: "
             f"{', '.join(map(str, leftover))}")
        raise SystemExit(1)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if which == "roofline":
        roofline_table(load("roofline"))
    elif which == "cascade":
        cascade_table(sys.argv[2] if len(sys.argv) > 2
                      else "results/BENCH_cascade.json")
    elif which == "scenarios":
        scenarios_table(sys.argv[2] if len(sys.argv) > 2
                        else "results/BENCH_scenarios.json")
    else:
        dryrun_table(load("scan"))
