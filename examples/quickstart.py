"""Quickstart: a semantic cache in 40 lines.

Builds the compact encoder, embeds a few queries, and shows the
hit/miss/threshold mechanics of the cache through the typed
plan/commit lifecycle (DESIGN.md §7).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.cache_service import CacheRequest
from repro.configs import get_config
from repro.core import EmbedderTrainer, FinetuneConfig, SemanticCache
from repro.data import HashTokenizer, make_pair_dataset

# 1. a compact embedder (reduced ModernBERT-family config; pass the full
#    `modernbert-149m` config on real hardware)
cfg = get_config("modernbert-149m").reduced(vocab_size=4096)
tok = HashTokenizer(vocab_size=cfg.vocab_size)
trainer = EmbedderTrainer(cfg, FinetuneConfig(epochs=2, batch_size=32,
                                              max_len=24, lr=5e-4,
                                              margin=0.7))

# 2. short domain fine-tuning (the paper's recipe: online contrastive
#    loss, grad-norm clip 0.5; 2 epochs for the 1000x-smaller smoke
#    model — the real 149M model needs just 1)
train_ds = make_pair_dataset("medical", 1024, seed=0)
stats = trainer.fit(train_ds, tok)
print(f"fine-tuned for {stats['steps']} steps "
      f"in {stats['train_seconds']:.1f}s")

# 3. the cache: embedding store + cosine threshold
cache = SemanticCache(capacity=1024, dim=cfg.d_model, threshold=0.85)
embed = trainer.make_embed_fn(tok)

queries = [
    "What are the symptoms of early-stage diabetes?",
    "How is hypertension treated?",
]
# plan: per-row hit/miss verdicts (all cold misses here)...
plan = cache.plan(CacheRequest.build(embed(queries)))
print("first lookup (cold):", list(plan.hit))
# ...then commit the generated answers for the planned misses
cache.commit(plan, ["<llm answer about diabetes symptoms>",
                    "<llm answer about hypertension treatment>"])

paraphrases = [
    # same intent, different surface form -> should HIT
    "Which warning signs point to early-stage diabetes?",
    # topically related but semantically distinct -> must MISS
    "What diet helps with early-stage diabetes?",
]
plan = cache.plan(CacheRequest.build(embed(paraphrases)))
for q, h, s, v in zip(paraphrases, plan.hit, plan.scores, plan.responses):
    print(f"  {'HIT ' if h else 'MISS'} score={s:.3f}  {q!r}"
          + (f" -> {v!r}" if h else ""))
print(f"cache occupancy: {cache.occupancy:.1%}  "
      f"stats: {cache.stats_snapshot()}")
