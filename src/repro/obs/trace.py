"""Span tracer: per-request stage timing as a tree (DESIGN.md §10.2).

A *span* is one named, timed region of a request's life
(``embed``/``plan``/``generate``/``commit``/``maintenance``); spans
nest, so one ``CachedLLMService.handle`` call produces one *span tree*
rooted at ``request``.  The tracer is deliberately tiny:

  * ``tracer.span(name, **attrs)`` is a context manager; entering
    pushes onto a plain stack (the serve loop is single-threaded —
    the shadow-rebuild thread never traces), exiting stamps the wall
    time and attaches the span to its parent.
  * Finished *root* spans land in a bounded ring (``keep`` most
    recent), inspectable via ``last_root()`` / ``drain()`` — the unit
    tests assert the full embed->plan->generate->commit tree from
    here, and an operator can dump recent request timelines without
    having wired an exporter.
  * With ``annotate_xla=True`` each span also enters a
    ``jax.profiler.TraceAnnotation``, so when a profiler trace is
    being captured the device work dispatched under a span shows up
    *attributed to that stage* in the XLA timeline (DESIGN.md §10.4).
    Outside an active capture the annotation is a few hundred
    nanoseconds of overhead.
  * Spans are structural; they do **not** write metrics (the serving
    layers observe the ``stage_latency_seconds`` histogram directly,
    exactly once per stage — see DESIGN.md §10.2 for why the two are
    kept separate).  Pass ``histogram=`` to opt a tracer into
    recording span durations anyway (used by tools that only have a
    tracer).

``NULL_TRACER`` (or ``Tracer(enabled=False)``) makes ``span()`` return
a shared reusable no-op context manager.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional

try:                                    # jax is a hard dep of the repo,
    from jax.profiler import TraceAnnotation   # but keep obs importable
except Exception:                       # against minimal environments
    TraceAnnotation = None


class Span:
    __slots__ = ("name", "attrs", "start_s", "end_s", "children")

    def __init__(self, name: str, attrs: Dict[str, object]):
        self.name = name
        self.attrs = attrs
        self.start_s = time.perf_counter()
        self.end_s: Optional[float] = None
        self.children: List["Span"] = []

    @property
    def duration_s(self) -> float:
        return (self.end_s or time.perf_counter()) - self.start_s

    def to_dict(self) -> Dict[str, object]:
        """Plain-dict tree (JSON-able)."""
        return {
            "name": self.name,
            "duration_s": self.duration_s,
            "attrs": dict(self.attrs),
            "children": [c.to_dict() for c in self.children],
        }

    def walk(self):
        """Pre-order iteration over the tree."""
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str) -> Optional["Span"]:
        for s in self.walk():
            if s.name == name:
                return s
        return None

    def stage_names(self) -> List[str]:
        """Direct children's names in completion order — the stage
        sequence of one request."""
        return [c.name for c in self.children]

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.duration_s * 1e3:.2f}ms, "
                f"{len(self.children)} children)")


class _SpanCtx:
    __slots__ = ("_tracer", "_name", "_attrs", "_span", "_ann")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Dict[str, object]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._span: Optional[Span] = None
        self._ann = None

    def __enter__(self) -> Span:
        t = self._tracer
        self._span = span = Span(self._name, self._attrs)
        t._stack.append(span)
        if t.annotate_xla and TraceAnnotation is not None:
            self._ann = TraceAnnotation(self._name)
            self._ann.__enter__()
        return span

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        t = self._tracer
        span = self._span
        span.end_s = time.perf_counter()
        # unwind to this span even if inner code leaked an open child
        while t._stack and t._stack[-1] is not span:
            t._stack.pop()
        if t._stack:
            t._stack.pop()
        if t._stack:
            t._stack[-1].children.append(span)
        else:
            t._roots.append(span)
        if t._histogram is not None:
            t._histogram.observe(
                span.duration_s, stage=span.name,
                tenant=str(span.attrs.get("tenant", "-")))


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return _NULL_SPAN

    def __exit__(self, *exc) -> None:
        pass


class _NullSpan:
    __slots__ = ()
    name = ""
    attrs: Dict[str, object] = {}
    duration_s = 0.0
    children: List[Span] = []

    def to_dict(self):
        return {}


_NULL_SPAN = _NullSpan()
_NULL_CTX = _NullCtx()


class Tracer:
    def __init__(self, *, enabled: bool = True, annotate_xla: bool = False,
                 keep: int = 64, histogram=None):
        """``keep``: finished root spans retained (ring buffer).
        ``histogram``: optional `repro.obs.registry.Histogram` with
        labels ``(stage, tenant)`` to observe on every span end."""
        self.enabled = bool(enabled)
        self.annotate_xla = bool(annotate_xla)
        self._stack: List[Span] = []
        self._roots: deque = deque(maxlen=keep)
        self._histogram = histogram

    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL_CTX
        return _SpanCtx(self, name, attrs)

    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    def last_root(self) -> Optional[Span]:
        return self._roots[-1] if self._roots else None

    def roots(self) -> List[Span]:
        return list(self._roots)

    def drain(self) -> List[Span]:
        out = list(self._roots)
        self._roots.clear()
        return out


NULL_TRACER = Tracer(enabled=False)
