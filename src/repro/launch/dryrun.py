import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production mesh and extract memory / cost / roofline artifacts.

THE TWO LINES ABOVE MUST STAY FIRST — jax locks the device count at
first init, and the dry-run needs 512 placeholder host devices for the
2×16×16 multi-pod mesh.  Nothing else (tests, benches) sets this flag.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --arch jamba-1.5-large-398b \
      --shape long_500k --multi-pod

Per run it prints/writes: compiled.memory_analysis() (proves the
per-device footprint), cost_analysis() (FLOPs/bytes for §Roofline), the
collective schedule summary, and the derived roofline terms.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.programs import get_program
from repro.launch.roofline import model_flops, roofline_terms
from repro.launch.sharding import RULE_SETS, sharding_tree


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            rules: str = "train", unroll: bool = True,
            overrides: dict | None = None, constrain_acts: bool = False,
            verbose: bool = True) -> dict:
    """unroll=True: layers unrolled for honest cost_analysis (slow
    compiles) — the single-pod §Roofline pass.  unroll=False: scanned
    layers — fast compiles, used for the multi-pod sharding-proof pass
    (cost numbers would undercount loop bodies, so only memory/compile
    success is recorded).  overrides: ModelConfig.replace kwargs for
    §Perf experiments (e.g. {"attn_f32": False, "loss_chunk": 512})."""
    t0 = time.perf_counter()
    prog = get_program(arch, shape_name, unroll=unroll, overrides=overrides,
                       multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rule_set = RULE_SETS[rules]

    fn = prog.fn
    if constrain_acts:
        from repro.models.actsharding import wrap_with_activation_constraints
        fn = wrap_with_activation_constraints(fn, mesh)

    in_sh = tuple(sharding_tree(a, ax, mesh, rule_set)
                  for a, ax in zip(prog.args, prog.arg_axes))
    out_sds = jax.eval_shape(prog.fn, *prog.args)
    out_sh = sharding_tree(out_sds, prog.out_axes, mesh, rule_set)

    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*prog.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    terms = roofline_terms(cost, hlo, n_dev)
    mf = model_flops(prog.cfg, prog.shape)
    hlo_total_flops = terms["per_device_flops"] * n_dev
    result = {
        "arch": arch,
        "shape": shape_name,
        "program": prog.name,
        "mesh": list(mesh.shape.values()),
        "multi_pod": multi_pod,
        "rules": rules,
        "unrolled": unroll,
        "overrides": overrides or {},
        "constrain_acts": constrain_acts,
        "config_name": prog.cfg.name,
        "param_count": prog.cfg.param_count(),
        "param_count_active": prog.cfg.param_count(active_only=True),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "peak_estimate_gib": (mem.argument_size_in_bytes
                                  + mem.temp_size_in_bytes) / 2**30,
        },
        "roofline": terms,
        "model_flops": mf,
        "hlo_total_flops": hlo_total_flops,
        "useful_flops_ratio": (mf / hlo_total_flops
                               if hlo_total_flops else 0.0),
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
    }
    if verbose:
        print(f"== {arch} × {shape_name} "
              f"({'multi-pod 2x16x16' if multi_pod else 'single-pod 16x16'}, "
              f"rules={rules}) ==")
        print(f"  program={prog.name}  params={result['param_count']:.3e} "
              f"(active {result['param_count_active']:.3e})")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB per device")
        print(f"  cost_analysis: flops/dev={terms['per_device_flops']:.3e} "
              f"bytes/dev={terms['per_device_bytes']:.3e}")
        print(f"  collectives/dev: {terms['per_device_collective_bytes']:.3e} B "
              f"{terms['collective_counts']}")
        print(f"  roofline: compute={terms['t_compute']*1e3:.2f}ms "
              f"memory={terms['t_memory']*1e3:.2f}ms "
              f"collective={terms['t_collective']*1e3:.2f}ms "
              f"-> bottleneck={terms['bottleneck']}")
        print(f"  MODEL_FLOPS/HLO_FLOPS={result['useful_flops_ratio']:.3f}  "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
    return result


def run_extrapolated(arch: str, shape_name: str, *, rules: str = "train",
                     multi_pod: bool = False, overrides: dict | None = None,
                     constrain_acts: bool = False,
                     verbose: bool = True) -> dict:
    """Roofline terms for huge-layer-count archs without compiling the
    full unrolled stack: lower 1-period and 2-period variants and scale
    the per-period delta —  X(N) = X(1) + (N-1)·(X(2) - X(1)).
    Exact for layer-linear terms (flops/bytes/collectives of identical
    stacked layers); embed/loss costs live in X(1).  Used only where
    the full unrolled compile is impractical on this 1-core container
    (granite-34b / jamba / qwen train_4k); marked in the output.
    """
    from repro.configs import get_config
    cfg = get_config(arch)
    period = len(cfg.period)
    n = cfg.n_periods

    results = []
    for k in (1, 2):
        sub = cfg.replace(n_layers=k * period, name=f"{cfg.name}-x{k}")
        # build the program directly from the sub-config
        from repro.launch.programs import build_program
        from repro.configs.base import INPUT_SHAPES
        prog = build_program(sub, INPUT_SHAPES[shape_name],
                             overrides=overrides)
        mesh = make_production_mesh(multi_pod=multi_pod)
        rule_set = RULE_SETS[rules]
        fn = prog.fn
        if constrain_acts:
            from repro.models.actsharding import (
                wrap_with_activation_constraints)
            fn = wrap_with_activation_constraints(fn, mesh)
        in_sh = tuple(sharding_tree(a, ax, mesh, rule_set)
                      for a, ax in zip(prog.args, prog.arg_axes))
        out_sds = jax.eval_shape(prog.fn, *prog.args)
        out_sh = sharding_tree(out_sds, prog.out_axes, mesh, rule_set)
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*prog.args
                                                           ).compile()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        mem = compiled.memory_analysis()
        coll = roofline_terms(cost, hlo, mesh.size)
        results.append({
            "flops": coll["per_device_flops"],
            "bytes": coll["per_device_bytes"],
            "coll": coll["per_device_collective_bytes"],
            "args": mem.argument_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
        })

    x1, x2 = results

    def ext(key):
        return x1[key] + (n - 1) * (x2[key] - x1[key])

    from repro.launch.mesh import (
        HBM_BANDWIDTH, ICI_LINK_BANDWIDTH, PEAK_FLOPS_BF16)
    mesh = make_production_mesh(multi_pod=multi_pod)
    prog = get_program(arch, shape_name)  # full cfg, for metadata only
    terms = {
        "per_device_flops": ext("flops"),
        "per_device_bytes": ext("bytes"),
        "per_device_collective_bytes": ext("coll"),
        "t_compute": ext("flops") / PEAK_FLOPS_BF16,
        "t_memory": ext("bytes") / HBM_BANDWIDTH,
        "t_collective": ext("coll") / ICI_LINK_BANDWIDTH,
        "collective_counts": {},
        "collective_top_ops": [],
        "collective_breakdown": {},
    }
    dom = max(("compute", "memory", "collective"),
              key=lambda k: terms[f"t_{k}"])
    terms["bottleneck"] = dom
    terms["t_bound"] = terms[f"t_{dom}"]
    terms["roofline_fraction"] = (terms["t_compute"] / terms["t_bound"]
                                  if terms["t_bound"] else 0.0)
    mf = model_flops(prog.cfg, prog.shape)
    hlo_total = terms["per_device_flops"] * mesh.size
    result = {
        "arch": arch, "shape": shape_name, "program": prog.name,
        "mesh": list(mesh.shape.values()), "multi_pod": multi_pod,
        "rules": rules, "unrolled": True, "extrapolated": True,
        "overrides": overrides or {}, "constrain_acts": constrain_acts,
        "config_name": prog.cfg.name,
        "param_count": prog.cfg.param_count(),
        "param_count_active": prog.cfg.param_count(active_only=True),
        "memory": {"argument_bytes_per_device": ext("args"),
                   "output_bytes_per_device": 0,
                   "temp_bytes_per_device": ext("temp"),
                   "peak_estimate_gib": (ext("args") + ext("temp")) / 2**30},
        "roofline": terms,
        "model_flops": mf,
        "hlo_total_flops": hlo_total,
        "useful_flops_ratio": mf / hlo_total if hlo_total else 0.0,
        "lower_seconds": 0.0, "compile_seconds": 0.0,
    }
    if verbose:
        print(f"== {arch} × {shape_name} (EXTRAPOLATED {n} periods) ==")
        print(f"  roofline: compute={terms['t_compute']*1e3:.2f}ms "
              f"memory={terms['t_memory']*1e3:.2f}ms "
              f"collective={terms['t_collective']*1e3:.2f}ms "
              f"-> bottleneck={dom}")
        print(f"  MODEL/HLO={result['useful_flops_ratio']:.3f} "
              f"args={ext('args')/2**30:.2f}GiB")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + ["cache_lookup", None])
    ap.add_argument("--all", action="store_true",
                    help="run every assigned arch × shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default="train", choices=list(RULE_SETS))
    ap.add_argument("--out", default=None, help="JSON output path prefix")
    ap.add_argument("--scan", action="store_true",
                    help="scanned layers (fast compile; multi-pod pass)")
    ap.add_argument("--attn-bf16", action="store_true",
                    help="§Perf: bf16 attention probs/accumulator")
    ap.add_argument("--param-bf16", action="store_true",
                    help="§Perf: bf16 master weights (serving)")
    ap.add_argument("--loss-chunk", type=int, default=0,
                    help="§Perf: fused chunked cross-entropy")
    ap.add_argument("--window", type=int, default=0,
                    help="§Perf ablation: sliding-window attention")
    ap.add_argument("--pad-vocab", type=int, default=0,
                    help="§Perf: pad vocab to a shardable multiple")
    ap.add_argument("--pad-experts", type=int, default=0,
                    help="§Perf H7: pad expert count (router-masked)")
    ap.add_argument("--constrain-acts", action="store_true",
                    help="§Perf H6: batch-anchor activation shardings")
    ap.add_argument("--extrapolate", action="store_true",
                    help="1/2-period lower + per-period scaling (for "
                         "88-layer unrolled trains on this container)")
    ap.add_argument("--tag", default="", help="suffix for --out files")
    args = ap.parse_args()
    overrides = {}
    if args.window:
        overrides["sliding_window"] = args.window
    if args.pad_vocab:
        overrides["pad_vocab_to"] = args.pad_vocab
    if args.pad_experts:
        overrides["pad_experts_to"] = args.pad_experts
    if args.attn_bf16:
        overrides["attn_f32"] = False
    if args.param_bf16:
        overrides["param_dtype"] = "bfloat16"
    if args.loss_chunk:
        overrides["loss_chunk"] = args.loss_chunk

    pairs = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results, failures = [], []
    for arch, shape in pairs:
        for mp in meshes:
            try:
                if args.extrapolate:
                    r = run_extrapolated(arch, shape, rules=args.rules,
                                         multi_pod=mp,
                                         overrides=overrides or None,
                                         constrain_acts=args.constrain_acts)
                else:
                    r = run_one(arch, shape, multi_pod=mp, rules=args.rules,
                                unroll=not args.scan,
                                overrides=overrides or None,
                                constrain_acts=args.constrain_acts)
                results.append(r)
                if args.out:
                    tag = f"{arch}_{shape}_{'mp' if mp else 'sp'}_{args.rules}"
                    if args.tag:
                        tag += f"_{args.tag}"
                    with open(f"{args.out}_{tag}.json", "w") as f:
                        json.dump(r, f, indent=1)
            except Exception as e:  # a failure here is a sharding bug
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    print(f"\n{len(results)} ok, {len(failures)} failed")
    for f in failures:
        print("  FAIL:", f)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
