from repro.kernels.contrastive.ops import online_contrastive_loss

__all__ = ["online_contrastive_loss"]
