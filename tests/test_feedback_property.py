"""Property tests for the online feedback loop (DESIGN.md §9/§11).

Two invariant families the unit tests in test_admission_learning.py
don't pin down:

  * **reservoir uniformity** — both reservoirs (scores and text pairs)
    run Vitter's algorithm R; every streamed event must be retained
    with equal probability C/N regardless of arrival position, or a
    drifting stream would bias every refit toward one era.
  * **"no refit fires"** — each hysteresis guard (`min_samples`,
    `min_class`, `refit_interval`, `max_step`) must *individually*
    suppress or bound a refit: a fit attempt under a tripped guard
    returns the caller's policy unchanged, and an applied refit never
    moves the threshold further than `max_step`.

Fuzzed with hypothesis when it is installed; otherwise each property
runs over a fixed deterministic case grid, so the invariants are
exercised in tier-1 either way.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.cache_service.feedback import (
    FeedbackAccumulator, FeedbackConfig, PairReservoir, TenantReservoir,
)
from repro.cache_service.policy import TenantPolicy

SETTINGS = dict(max_examples=25, deadline=None)


def fuzz(fallback_cases, *strategies):
    """``@given(*strategies)`` when hypothesis is available, else a
    parametrize over ``fallback_cases`` (tuples of the same arity)."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(**SETTINGS)(given(*strategies)(fn))

        def run(case):
            fn(*case)
        run.__name__ = fn.__name__      # not functools.wraps: pytest
        run.__doc__ = fn.__doc__        # would introspect __wrapped__
        return pytest.mark.parametrize("case", fallback_cases)(run)
    return deco


# ---------------------------------------------------------------------------
# reservoir bookkeeping invariants (any capacity, any stream length)
# ---------------------------------------------------------------------------

_FILL_CASES = [(1, 0, 0), (1, 7, 1), (8, 8, 2), (8, 300, 3),
               (64, 17, 4), (64, 300, 5), (33, 100, 6)]
_fill_strategies = (st.integers(1, 64), st.integers(0, 300),
                    st.integers(0, 10**6)) if HAVE_HYPOTHESIS else ()


@fuzz(_FILL_CASES, *_fill_strategies)
def test_tenant_reservoir_fill_and_seen(cap, n, seed):
    res = TenantReservoir(cap, np.random.default_rng(seed))
    for i in range(n):
        res.add(i / max(n, 1), i % 2 == 0)
    assert res.seen == n
    assert res.fill == min(n, cap)
    scores, labels = res.arrays()
    assert len(scores) == len(labels) == res.fill
    assert np.all(scores <= 1.0) and np.all(scores >= -1.0)


@fuzz(_FILL_CASES, *_fill_strategies)
def test_pair_reservoir_fill_and_content(cap, n, seed):
    res = PairReservoir(cap, np.random.default_rng(seed))
    streamed = set()
    for i in range(n):
        res.add(f"q{i}", f"n{i}", i % 3 == 0)
        streamed.add((f"q{i}", f"n{i}", 1 if i % 3 == 0 else 0))
    assert res.seen == n
    assert len(res) == min(n, cap)
    assert res.n_pos + res.n_neg == len(res)
    # the sample is a subset of the stream, labels intact
    assert set(res.items) <= streamed


_SPLIT_CASES = [(2, 0.5, 0), (5, 0.25, 1), (17, 0.1, 2), (40, 0.6, 3),
                (9, 0.33, 4)]
_split_strategies = (st.integers(2, 40), st.floats(0.05, 0.6),
                     st.integers(0, 10**6)) if HAVE_HYPOTHESIS else ()


@fuzz(_SPLIT_CASES, *_split_strategies)
def test_pair_reservoir_split_partitions(n, eval_frac, seed):
    res = PairReservoir(64, np.random.default_rng(seed))
    for i in range(n):
        res.add(f"q{i}", f"n{i}", i % 2 == 0)
    train, ev = res.split(eval_frac, seed=seed)
    assert len(ev.labels) == int(np.ceil(len(res) * eval_frac))
    assert len(train.labels) + len(ev.labels) == len(res)
    # deterministic: the same reservoir state yields the same split
    train2, ev2 = res.split(eval_frac, seed=seed)
    assert list(train.q1) == list(train2.q1)
    assert list(ev.q1) == list(ev2.q1)
    # disjoint partition of the sample
    assert set(zip(train.q1, train.q2)) | set(zip(ev.q1, ev.q2)) \
        == {(q, nb) for q, nb, _ in res.items}


# ---------------------------------------------------------------------------
# algorithm-R uniformity (deterministic statistical check: the property
# is about inclusion frequency *across* seeds)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("reservoir_cls", [TenantReservoir, PairReservoir])
def test_reservoir_uniform_over_stream(reservoir_cls):
    cap, n, trials = 32, 128, 400
    counts = np.zeros(n)
    for t in range(trials):
        res = reservoir_cls(cap, np.random.default_rng(t))
        for i in range(n):
            if reservoir_cls is TenantReservoir:
                res.add(i / n, False)
            else:
                res.add(str(i), str(i), False)
        if reservoir_cls is TenantReservoir:
            kept = np.rint(res.arrays()[0] * n).astype(int)
        else:
            kept = [int(q) for q, _, _ in res.items]
        counts[kept] += 1
    freq = counts / trials
    expect = cap / n
    # per-item inclusion frequency: ~5.5 sd tolerance at 400 trials
    assert np.all(np.abs(freq - expect) < 0.12), \
        f"max dev {np.abs(freq - expect).max():.3f}"
    # no era bias: first and second half of the stream carry equal mass
    assert abs(freq[:n // 2].mean() - freq[n // 2:].mean()) < 0.03


# ---------------------------------------------------------------------------
# "no refit fires" under each hysteresis guard
# ---------------------------------------------------------------------------

def _feed(acc, tenant, scores, labels):
    for s, d in zip(scores, labels):
        acc.observe(tenant, float(s), bool(d), admitted=True)


def _policy():
    return TenantPolicy(threshold=0.85, admission_margin=0.02)


@fuzz([(0, 0), (1, 1), (16, 2), (31, 3)],
      *((st.integers(0, 31), st.integers(0, 10**6))
        if HAVE_HYPOTHESIS else ()))
def test_guard_min_samples(n, seed):
    """Below min_samples no refit is due and a forced fit is refused."""
    cfg = FeedbackConfig(min_samples=32, seed=seed)
    acc = FeedbackAccumulator(cfg)
    rng = np.random.default_rng(seed)
    _feed(acc, 0, rng.random(n), rng.integers(0, 2, n))
    assert not acc.refit_due(0)
    pol = _policy()
    out, rep = acc.fit(0, pol)
    assert not rep.applied and rep.reason == "min-samples"
    assert out is pol


@fuzz([(0, 0), (3, 1), (7, 2), (5, 3)],
      *((st.integers(0, 7), st.integers(0, 10**6))
        if HAVE_HYPOTHESIS else ()))
def test_guard_min_class(n_dup, seed):
    """Enough events but one starved class: the fit is refused."""
    cfg = FeedbackConfig(min_samples=16, min_class=8, refit_interval=1,
                         seed=seed)
    acc = FeedbackAccumulator(cfg)
    rng = np.random.default_rng(seed)
    n = 64
    labels = np.zeros(n, bool)
    labels[:n_dup] = True           # fewer duplicates than min_class
    _feed(acc, 0, rng.random(n), labels)
    pol = _policy()
    out, rep = acc.fit(0, pol)
    assert not rep.applied and rep.reason == "class-starved"
    assert out is pol


@fuzz([(0, 0), (1, 1), (30, 2), (63, 3)],
      *((st.integers(0, 63), st.integers(0, 10**6))
        if HAVE_HYPOTHESIS else ()))
def test_guard_refit_interval(n_new, seed):
    """After one examination, fewer than refit_interval new events
    means the tenant is not re-examined."""
    cfg = FeedbackConfig(min_samples=16, min_class=4, refit_interval=64,
                         seed=seed)
    acc = FeedbackAccumulator(cfg)
    rng = np.random.default_rng(seed)
    scores = np.concatenate([rng.uniform(0.8, 1.0, 32),
                             rng.uniform(0.0, 0.5, 32)])
    labels = np.concatenate([np.ones(32, bool), np.zeros(32, bool)])
    _feed(acc, 0, scores, labels)
    pol, _ = acc.fit(0, _policy())         # first examination
    _feed(acc, 0, rng.random(n_new), rng.integers(0, 2, n_new))
    assert not acc.refit_due(0)            # n_new < refit_interval
    out, rep = acc.fit(0, pol)
    assert not rep.applied and rep.reason == "interval"
    assert out is pol


@fuzz([(32, 0.5, 0), (64, 0.2, 1), (200, 0.8, 2), (100, 0.5, 3),
       (50, 0.95, 4), (50, 0.05, 5)],
      *((st.integers(32, 200), st.floats(0.0, 1.0),
         st.integers(0, 10**6)) if HAVE_HYPOTHESIS else ()))
def test_guard_max_step_bounds_any_applied_refit(n, dup_frac, seed):
    """Whatever the reservoir says, one applied refit never moves the
    threshold more than max_step, and a loosening never breaches the
    observed false-hit budget."""
    cfg = FeedbackConfig(min_samples=16, min_class=4, refit_interval=1,
                         max_step=0.02, seed=seed)
    acc = FeedbackAccumulator(cfg)
    rng = np.random.default_rng(seed)
    labels = rng.random(n) < dup_frac
    # duplicates score high-ish, distincts low-ish, with overlap
    scores = np.where(labels, rng.uniform(0.5, 1.0, n),
                      rng.uniform(0.0, 0.8, n))
    _feed(acc, 0, scores, labels)
    pol = _policy()
    out, rep = acc.fit(0, pol)
    if not rep.applied:
        assert out is pol
        assert rep.new_threshold == rep.old_threshold
        return
    assert abs(rep.new_threshold - rep.old_threshold) <= cfg.max_step + 1e-9
    assert out.threshold == rep.new_threshold
    if rep.new_threshold < rep.old_threshold:
        res_scores, res_labels = acc._res[0].arrays()
        neg = res_scores[res_labels == 0]
        assert (neg >= rep.new_threshold).mean() <= cfg.max_false_hit_rate
    assert 0.0 <= out.admission_margin <= cfg.max_margin
