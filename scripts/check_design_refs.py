#!/usr/bin/env python3
"""Doc lint: every ``DESIGN.md §N`` reference must resolve to a real
section heading in DESIGN.md.

Scans Python sources under src/, benchmarks/, examples/, tests/ and
scripts/ for references of the form ``DESIGN.md §3``, ``DESIGN.md
§5-6`` (numeric ranges expand) or ``DESIGN.md §Arch-applicability``,
including references wrapped across a line break, and checks DESIGN.md
contains a heading whose anchor is ``§<id>``.  Exits non-zero listing
every dangling reference (CI runs this on every push).
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "scripts")
# \s* spans newlines, so "DESIGN.md\n§3" in a wrapped docstring matches
REF = re.compile(r"DESIGN\.md\s*§([A-Za-z0-9][A-Za-z0-9_-]*)")
HEADING = re.compile(r"^#{1,6}\s*§([A-Za-z0-9][A-Za-z0-9_-]*)\b",
                     re.MULTILINE)


def expand(ref: str) -> list[str]:
    """'5-6' -> ['5', '6']; anything else passes through."""
    m = re.fullmatch(r"(\d+)-(\d+)", ref)
    if m:
        lo, hi = int(m.group(1)), int(m.group(2))
        if lo <= hi:
            return [str(n) for n in range(lo, hi + 1)]
    return [ref]


def main() -> int:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist but the tree cites it")
        return 1
    sections = set(HEADING.findall(design.read_text()))
    # a numeric heading like '§3' also anchors dotted subsections (§3.1)
    dangling = []
    n_refs = 0
    self_path = pathlib.Path(__file__).resolve()
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            if path.resolve() == self_path:  # §N placeholders above
                continue
            text = path.read_text()
            for m in REF.finditer(text):
                for sec in expand(m.group(1)):
                    n_refs += 1
                    if sec not in sections:
                        line = text.count("\n", 0, m.start()) + 1
                        dangling.append(
                            f"{path.relative_to(ROOT)}:{line}: "
                            f"DESIGN.md §{sec} has no matching heading")
    if dangling:
        print(f"FAIL: {len(dangling)} dangling DESIGN.md reference(s):")
        print("\n".join(dangling))
        print(f"\nheadings present: "
              f"{', '.join(sorted(sections, key=str))}")
        return 1
    print(f"OK: {n_refs} DESIGN.md §-references resolve against "
          f"{len(sections)} section headings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
