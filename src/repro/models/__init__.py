from repro.models.model import (
    decode_step,
    encode,
    forward_lm,
    init_lm,
    init_lm_state,
    lm_loss,
    lm_param_specs,
    lm_state_axes,
    prefill,
)
from repro.models.param import Param, split, merge, param_bytes

__all__ = [
    "decode_step", "encode", "forward_lm", "init_lm", "init_lm_state",
    "lm_loss", "lm_param_specs", "lm_state_axes", "prefill",
    "Param", "split", "merge", "param_bytes",
]
