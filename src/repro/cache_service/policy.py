"""Per-tenant operating policy: thresholds and admission.

The paper evaluates one global best-F1 threshold; a multi-tenant
deployment runs one *operating point per tenant* (a medical tenant
tolerates far fewer false hits than a chit-chat tenant).  Policies are
plain host-side records resolved to per-query arrays at lookup time —
the device functions only ever see traced (Q,) float thresholds, so a
mixed-tenant batch costs zero recompiles.

Admission: caching every miss fills the store with near-duplicates
(paraphrase clusters collapse onto one representative anyway).  The
score-margin rule skips inserting a miss whose best same-tenant score
already sits within ``admission_margin`` of the hit threshold — the
next paraphrase of that query would have hit the *existing* entry.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.calibration import (
    Calibration, calibrate_for_false_hit_budget,
)


@dataclass(frozen=True)
class TenantPolicy:
    threshold: float = 0.85        # hit operating point
    admission_margin: float = 0.0  # skip insert if score >= thr - margin
    calibration: Optional[Calibration] = None

    def with_threshold(self, threshold: float,
                       calibration: Optional[Calibration] = None
                       ) -> "TenantPolicy":
        """Move the operating point, rescaling the admission margin to
        the new threshold's scale.

        The margin models paraphrase spread: entries whose paraphrases
        would already hit the stored neighbour.  That spread is set by
        the threshold itself — at thr 0.95 paraphrases land within
        ~0.05 of each other, at thr 0.85 within ~0.15 — so a margin
        carried over verbatim after a recalibration is wrong in
        *relative* terms (a 0.2 margin under a looser threshold skips
        admissions for far less similar queries than it was tuned
        for).  Keeping ``margin / (1 - threshold)`` constant preserves
        the band's width in units of the operating point's own
        paraphrase scale.

        Two safety caps keep the rescale from ever disabling
        admission: the ratio itself is capped at 2 (an old threshold
        sitting at ~1.0 would otherwise amplify any margin without
        bound), and the rescaled margin is capped at ``threshold/2``
        so the admission band's bottom stays at or above half the
        operating point — a query with no real similarity to the
        store is always admitted.
        """
        ratio = min(self.admission_margin
                    / max(1.0 - self.threshold, 1e-6), 2.0)
        margin = min(ratio * (1.0 - threshold),
                     0.5 * max(threshold, 0.0))
        return replace(self, threshold=threshold,
                       admission_margin=float(np.clip(margin, 0.0, 1.0)),
                       calibration=calibration if calibration is not None
                       else self.calibration)


@dataclass(frozen=True)
class EmbedderRefreshPolicy:
    """Operating policy of the online embedder refresh (DESIGN.md §11).

    The refresh trigger mirrors the admission-refit hysteresis: no
    training run below ``min_pairs`` pooled labeled pairs or
    ``min_class`` of either label, and at least ``refresh_interval``
    *new* pair events between runs, so the background trainer never
    thrashes.  The eval gate judges the candidate on a held-out
    ``eval_frac`` slice of the pair reservoir: it must clear the
    absolute precision/recall floors *and* not regress the frozen
    embedder's F1 on the same slice by more than
    ``max_f1_regression`` — otherwise the candidate is discarded
    (rollback) and the live embedder keeps serving.

    ``synth_domain`` enables the paper's synthetic augmentation: when
    the training split is thinner than ``synth_min_pairs`` — or either
    split is missing a label class — it is topped up with
    grammar-generated paraphrase/distinct pairs from that domain
    (`core/synth.py`), exactly the dual-labeling pass the paper uses
    to bootstrap thin domains.  It also waives the ``min_class``
    trigger guard: a one-sided pool (a stream where every observed
    neighbour really was a duplicate) is precisely what the backfill
    balances, so it must not block the refresh.

    ``recalibrate`` acknowledges that a serving threshold is only
    meaningful relative to one embedder's score distribution: a
    published candidate scores the same pairs on a different scale, so
    carrying the old scalar across the swap silently moves every
    tenant to an arbitrary point on the new ROC curve.  When enabled,
    publish remaps the default and every per-tenant threshold to the
    candidate's best-F1 operating point on the held-out gate slice
    (margins rescale via ``TenantPolicy.with_threshold``) and drops
    the §9 score reservoirs, whose samples were observed in the old
    embedder's space.
    """
    min_pairs: int = 64          # no refresh below this many pairs
    min_class: int = 8           # ... or this many of either label
    refresh_interval: int = 256  # new pair events between refreshes
    eval_frac: float = 0.25      # held-out slice for the eval gate
    min_precision: float = 0.5   # gate floor: candidate precision
    min_recall: float = 0.5      # gate floor: candidate recall
    max_f1_regression: float = 0.02  # gate: vs frozen F1 on the slice
    synth_domain: Optional[str] = None   # grammar domain for backfill
    synth_min_pairs: int = 256   # top training split up to this size
    synth_seed: int = 0
    seed: int = 0                # split permutation seed
    recalibrate: bool = False    # remap thresholds to the candidate's
                                 # operating point at publish
    # clip band for the adopted threshold: the gate slice's synthetic
    # negatives can be easier than live traffic, in which case its
    # best-F1 point is an over-permissive operating point for a cache
    # — the floor keeps the published version conservative
    recalibrate_bounds: Tuple[float, float] = (0.7, 0.99)


@dataclass(frozen=True)
class ColdRoutingPolicy:
    """Operating policy of the host-RAM cold tier (DESIGN.md §12).

    The router's decision rule — consult the cold tier only when the
    warm/hot verdict missed AND the best cold-centroid similarity
    clears ``threshold - router_margin - route_slack`` — makes the
    host→device fetch conditional on a plausible hit: a coarse
    centroid that far below the operating point bounds every member
    row away from it, so the fetch would be wasted motion.  The slack
    term is *calibrated by the tier at route-fit time* (the observed
    q10 member→centroid spread, `ColdTier.rebuild_routes`), so the
    gate tracks how coarse the clustering actually is;
    ``router_margin`` is the fixed conservatism added on top — raise
    it to fetch more speculatively, at host-scan and PCIe cost.
    ``fetch_budget`` caps the rows any
    single query ships to the device for the exact re-score (the
    approximate int8 host ranking picks which), keeping plan-time cold
    cost O(budget·D) per consulted query regardless of corpus size.

    Routing maintenance is bounded: centroids fit on at most
    ``kmeans_sample`` sampled rows, re-fit every
    ``route_rebuild_every`` inserts (or at first crossing of
    ``min_rows_for_routing`` — below that the corpus is scanned
    unrouted, which is cheaper than maintaining an index for it).
    ``promote_max`` caps how many re-hot rows one maintenance tick
    drains back into the warm ring.
    """
    n_probe: int = 4             # coarse clusters consulted per query
    fetch_budget: int = 32       # device re-score rows per query
    router_margin: float = 0.05  # consult if csim >= thr-margin-slack
    promote_max: int = 64        # promotions drained per idle tick
    n_clusters: int = 64
    kmeans_iters: int = 6
    kmeans_sample: int = 65536   # routing fit sample bound
    route_rebuild_every: int = 8192   # inserts between route re-fits
    min_rows_for_routing: int = 512   # below: brute-force, no index
    seed: int = 0


class PolicyTable:
    """tenant id -> TenantPolicy, with a default for unknown tenants.

    Under a fused multi-embedder ensemble (DESIGN.md §13) the table
    also owns per-tenant **mixture weights**: the (E,) convex weights
    the cascade fuses the per-embedder cosines with.  Like thresholds,
    they resolve to a per-query (Q, E) array at lookup time (uniform
    1/E for tenants with no learned weights) and are re-learned at
    refit time from the feedback stream (`refit_weights`).
    """

    def __init__(self, default: TenantPolicy):
        self.default = default
        self._by_tenant: Dict[int, TenantPolicy] = {}
        self._weights: Dict[int, np.ndarray] = {}        # §13
        self._default_weights: Optional[np.ndarray] = None

    def get(self, tenant: int) -> TenantPolicy:
        return self._by_tenant.get(int(tenant), self.default)

    def set(self, tenant: int, policy: TenantPolicy) -> None:
        self._by_tenant[int(tenant)] = policy

    def recalibrate_all(self, threshold: float) -> None:
        """Move the default and every per-tenant policy to a new
        operating point — the embedder-publish path (§11): the score
        space just changed under every threshold in the table, learned
        or configured, so all of them remap together (margins rescale
        per ``with_threshold``)."""
        self.default = self.default.with_threshold(threshold)
        for t, pol in self._by_tenant.items():
            self._by_tenant[t] = pol.with_threshold(threshold)

    def calibrate(self, tenant: int, scores, labels,
                  max_false_hit_rate: float = 0.01) -> Calibration:
        """Fit this tenant's threshold to a false-hit budget from its
        own scored eval pairs (repro.core.calibration).  The admission
        margin is rescaled to the new threshold's paraphrase scale —
        carrying it over verbatim silently changed the band's relative
        width every time the threshold moved (see
        ``TenantPolicy.with_threshold``)."""
        cal = calibrate_for_false_hit_budget(scores, labels,
                                             max_false_hit_rate)
        cur = self.get(tenant)
        self.set(tenant, cur.with_threshold(cal.threshold, calibration=cal))
        return cal

    def refit(self, feedback) -> List[object]:
        """Online refit from a ``FeedbackAccumulator`` (DESIGN.md §9):
        every tenant whose reservoir says a refit is due gets one
        ``feedback.fit()`` — the accumulator owns the estimators and
        every hysteresis guard; this table only publishes the policies
        that survive them.  Returns the ``RefitReport`` list (applied
        and refused) for the maintenance report and stats."""
        reports = []
        for tenant in feedback.tenants():
            if not feedback.refit_due(tenant):
                continue
            policy, report = feedback.fit(tenant, self.get(tenant))
            if report.applied:
                self.set(tenant, policy)
            reports.append(report)
        return reports

    # ----- §13 ensemble mixture weights --------------------------------
    def set_default_weights(self, weights) -> None:
        """Default mixture for tenants with no learned weights
        (normalized to the simplex; None reverts to uniform 1/E)."""
        if weights is None:
            self._default_weights = None
            return
        w = np.asarray(weights, np.float32)
        if w.ndim != 1 or np.any(w < 0) or w.sum() <= 0:
            raise ValueError(f"ensemble weights must be a non-negative "
                             f"1-D vector with positive sum, got {w!r}")
        self._default_weights = w / w.sum()

    def set_weights(self, tenant: int, weights) -> None:
        w = np.asarray(weights, np.float32)
        if np.any(w < 0) or w.sum() <= 0:
            raise ValueError("tenant mixture weights must be "
                             "non-negative with positive sum")
        self._weights[int(tenant)] = w / w.sum()

    def get_weights(self, tenant: int, n_embedders: int) -> np.ndarray:
        w = self._weights.get(int(tenant), self._default_weights)
        if w is None:
            return np.full(n_embedders, 1.0 / n_embedders, np.float32)
        if len(w) != n_embedders:
            raise ValueError(f"weights of len {len(w)} vs "
                             f"{n_embedders} embedders")
        return w

    def weights_for(self, tenants: np.ndarray,
                    n_embedders: int) -> np.ndarray:
        """Per-query (Q, E) mixture weights — the vectorized resolution
        the cascade consumes, mirroring `thresholds_for`."""
        return np.stack([self.get_weights(t, n_embedders)
                         for t in tenants])

    def refit_weights(self, feedback, n_embedders: int) -> List[object]:
        """Drive `feedback.fit_weights` over every tenant whose
        ensemble reservoir says a refit is due — the §13 twin of
        `refit`.  An applied fit publishes the tenant's weights AND the
        threshold recalibrated against the new fused score, atomically
        from the table's point of view.  Returns the
        ``WeightRefitReport`` list."""
        reports = []
        for tenant in feedback.ensemble_tenants():
            if not feedback.weight_refit_due(tenant):
                continue
            w, policy, report = feedback.fit_weights(
                tenant, self.get_weights(tenant, n_embedders),
                self.get(tenant))
            if report.applied:
                self._weights[int(tenant)] = np.asarray(w, np.float32)
                self.set(tenant, policy)
            reports.append(report)
        return reports

    def weights_state(self) -> Dict[int, List[float]]:
        """Published per-tenant mixtures (the §13 stats view)."""
        return {t: [float(x) for x in w]
                for t, w in sorted(self._weights.items())}

    def learned_state(self) -> Dict[int, Dict[str, float]]:
        """Per-tenant operating points currently published (the
        learned-admission view exposed by ``stats()``)."""
        return {t: {"threshold": p.threshold,
                    "admission_margin": p.admission_margin}
                for t, p in sorted(self._by_tenant.items())}

    # ----- vectorised resolution for a query batch ---------------------
    def thresholds_for(self, tenants: np.ndarray) -> np.ndarray:
        return np.asarray([self.get(t).threshold for t in tenants],
                          np.float32)

    def effective_thresholds(self, tenants: np.ndarray,
                             feedback=None) -> np.ndarray:
        """Per-query serving thresholds with the §14.3 conformal floor
        applied: ``max(policy threshold, conformal floor)`` per tenant.
        The learned/configured threshold still *tightens* freely; the
        floor only ever raises it — under drift the §9 refit can lag
        (or loosen onto a stale reservoir) while the recency-window
        floor tracks the current negative-score distribution, so the
        false-hit budget holds through the transition.  ``feedback``
        None (conformal off, or no accumulator) degrades to
        ``thresholds_for``."""
        thr = self.thresholds_for(tenants)
        if feedback is None:
            return thr
        floors = np.asarray(
            [f if (f := feedback.conformal_floor(t)) is not None
             else -1.0 for t in tenants], np.float32)
        return np.maximum(thr, floors)

    def admit_mask(self, tenants: np.ndarray,
                   scores: Optional[np.ndarray]) -> np.ndarray:
        """Admission decision per miss: True -> cache it."""
        if scores is None:
            return np.ones(len(tenants), bool)
        thr = self.thresholds_for(tenants)
        margin = np.asarray([self.get(t).admission_margin for t in tenants],
                            np.float32)
        return np.asarray(scores, np.float32) < thr - margin

    def pre_decision(self, tenants: np.ndarray, scores: np.ndarray,
                     hit: np.ndarray) -> np.ndarray:
        """Plan-time admission pre-decision (DESIGN.md §7): False on hit
        rows; on miss rows the score-margin rule over the observed
        neighbour scores.  Carried inside the ``CachePlan`` so commit
        honors the decision taken when the scores were observed."""
        return ~np.asarray(hit, bool) & self.admit_mask(tenants, scores)
