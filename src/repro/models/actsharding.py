"""Activation sharding constraints (§Perf H6).

GSPMD propagates *weight* shardings into activations: the FSDP-sharded
embedding table (embed→data) makes the embedding output — and from
there the whole network — run batch-REPLICATED and embed-sharded, which
is catastrophic (the dry-run showed every large collective carrying
B=256 unsharded tensors).  The standard fix (MaxText) is to anchor
activations with explicit with_sharding_constraint(batch→data axes) so
XLA all-gathers the weights instead of replicating the batch.

Model code cannot know the mesh axes; the launcher installs them via a
contextvar *at trace time* (`activation_ctx`).  Outside any context the
constraint is a no-op, so tests and single-device runs are untouched.
"""
from __future__ import annotations

import contextlib
import math
from contextvars import ContextVar
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec

_BATCH_AXES: ContextVar[Optional[Tuple[Tuple[str, ...], Tuple[int, ...]]]] = \
    ContextVar("repro_batch_axes", default=None)


@contextlib.contextmanager
def activation_ctx(mesh, batch_axes=("pod", "data")):
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    sizes = tuple(mesh.shape[a] for a in axes)
    token = _BATCH_AXES.set((axes, sizes))
    try:
        yield
    finally:
        _BATCH_AXES.reset(token)


def constrain_batch(x):
    """Anchor the leading (batch) dim of an activation to the data axes;
    no-op when no context is installed or the batch doesn't divide."""
    ctx = _BATCH_AXES.get()
    if ctx is None:
        return x
    axes, sizes = ctx
    while axes and x.shape[0] % math.prod(sizes) != 0:
        axes, sizes = axes[1:], sizes[1:]   # drop 'pod' first
    if not axes:
        return x
    spec = PartitionSpec(axes if len(axes) > 1 else axes[0],
                         *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def wrap_with_activation_constraints(fn, mesh):
    """Launcher-side: run fn's TRACE inside the activation context."""
    def wrapped(*args, **kw):
        with activation_ctx(mesh):
            return fn(*args, **kw)
    return wrapped
