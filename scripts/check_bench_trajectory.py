"""CI perf-trajectory gate over ``BENCH_cascade.json``.

``bench_tiered_cache`` writes every row of each run to a
machine-readable JSON; the copy committed under ``results/`` is the
perf trajectory baseline.  This gate compares a fresh ``--smoke`` run
against it so a PR cannot silently regress what the bench measures:

  * every baseline row must still exist in the fresh run (a vanished
    row means a bench path was dropped, which must be an explicit
    baseline update, never an accident);
  * recall fields (``recall_at_thr``, ``recall_probe``) must not fall
    more than ``--recall-eps`` below the baseline;
  * ``p50_us`` may not exceed ``baseline * --p50-tolerance`` — latency
    ratios, not absolutes, and only when the fresh run's backend AND
    device count match the baseline's.  The fleet tuple is coarse (a
    dev laptop and a hosted CI runner both say ``cpu x1``), so the
    default tolerance is deliberately wide: it exists to catch
    order-of-magnitude cliffs (an accidental recompile per batch, an
    O(N) scan on the hot path), not machine-to-machine jitter.
    Tighten ``--p50-tolerance`` only where baseline and CI hardware
    genuinely match; a mismatched fleet skips the latency check and
    says so;
  * a baseline row whose size tier is absent from the fresh sweep is
    skipped with a note (a full-sweep baseline must not fail every
    ``--smoke`` run on rows the smoke tier cannot produce);
  * the learned-admission claim is re-checked on the artifacts: the
    ``admission_learned`` row must keep ``dup_admissions`` strictly
    below ``admission_fixed``'s and its false-hit probes at zero-ish
    (<= the fixed row's).

Exit 0 when clean; exit 1 with one line per violation.

    python scripts/check_bench_trajectory.py \
        --baseline results/BENCH_cascade.json \
        --fresh /tmp/BENCH_cascade_fresh.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Tuple

RECALL_FIELDS = ("recall_at_thr", "recall_probe")


def load(path: str) -> Dict[str, object]:
    with open(path) as f:
        return json.load(f)


def _rows(data: Dict[str, object]) -> Dict[str, Dict[str, object]]:
    return {r["name"]: r for r in data.get("rows", [])}


_SIZE_RE = re.compile(r"^tiered/(\d+)k/")


def _comparable(name: str, fresh_sizes) -> bool:
    """A baseline row is only owed by the fresh run when the fresh
    sweep covers its size tier: a full-sweep baseline (16k/64k/256k
    rows) must not make every --smoke run (4k only) fail on rows the
    smoke tier can never produce.  Size-independent rows (admission,
    …) are always owed."""
    m = _SIZE_RE.match(name)
    if m is None:
        return True
    return int(m.group(1)) * 1024 in set(fresh_sizes or [])


def compare(baseline: Dict[str, object], fresh: Dict[str, object],
            recall_eps: float = 0.005,
            p50_tolerance: float = 5.0) -> Tuple[List[str], List[str]]:
    """Returns (violations, notes).  Violations fail the gate; notes
    explain what was skipped or newly added."""
    violations: List[str] = []
    notes: List[str] = []
    base_rows = _rows(baseline)
    fresh_rows = _rows(fresh)

    same_fleet = (baseline.get("backend") == fresh.get("backend")
                  and baseline.get("devices") == fresh.get("devices"))
    if not same_fleet:
        notes.append(
            f"fleet mismatch (baseline {baseline.get('backend')}"
            f"x{baseline.get('devices')} vs fresh {fresh.get('backend')}"
            f"x{fresh.get('devices')}): p50 ratios not compared")

    fresh_sizes = fresh.get("sizes", [])
    for name, base in base_rows.items():
        if not _comparable(name, fresh_sizes):
            notes.append(f"{name}: size tier not in the fresh sweep "
                         f"{fresh_sizes}; skipped")
            continue
        row = fresh_rows.get(name)
        if row is None:
            violations.append(
                f"{name}: row present in baseline but missing from the "
                "fresh run (bench path dropped?)")
            continue
        for field in RECALL_FIELDS:
            if field in base:
                if field not in row:
                    violations.append(f"{name}: {field} vanished from "
                                      "the fresh run")
                elif row[field] < base[field] - recall_eps:
                    violations.append(
                        f"{name}: {field} regressed "
                        f"{base[field]:.4f} -> {row[field]:.4f} "
                        f"(eps {recall_eps})")
        if same_fleet and "p50_us" in base and "p50_us" in row:
            if row["p50_us"] > base["p50_us"] * p50_tolerance:
                violations.append(
                    f"{name}: p50 {row['p50_us']:.0f}us exceeds "
                    f"{p50_tolerance:.1f}x the baseline "
                    f"{base['p50_us']:.0f}us")

    for name in sorted(set(fresh_rows) - set(base_rows)):
        notes.append(f"{name}: new row (not in baseline)")

    fixed = fresh_rows.get("tiered/admission_fixed")
    learned = fresh_rows.get("tiered/admission_learned")
    if fixed is not None and learned is not None:
        if learned["dup_admissions"] >= fixed["dup_admissions"]:
            violations.append(
                "admission: learned dup_admissions "
                f"{learned['dup_admissions']} not below fixed "
                f"{fixed['dup_admissions']}")
        if learned["false_hits_probe"] > fixed["false_hits_probe"]:
            violations.append(
                "admission: learned false_hits_probe "
                f"{learned['false_hits_probe']} exceeds fixed "
                f"{fixed['false_hits_probe']}")
    return violations, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="results/BENCH_cascade.json",
                    help="committed perf-trajectory baseline")
    ap.add_argument("--fresh", required=True,
                    help="JSON written by the fresh bench run")
    ap.add_argument("--recall-eps", type=float, default=0.005,
                    help="tolerated absolute recall drop per row")
    ap.add_argument("--p50-tolerance", type=float, default=5.0,
                    help="max fresh/baseline p50 ratio (same fleet only)")
    args = ap.parse_args(argv)

    violations, notes = compare(load(args.baseline), load(args.fresh),
                                recall_eps=args.recall_eps,
                                p50_tolerance=args.p50_tolerance)
    for n in notes:
        print(f"note: {n}")
    if violations:
        for v in violations:
            print(f"VIOLATION: {v}", file=sys.stderr)
        print(f"perf trajectory gate: {len(violations)} violation(s)",
              file=sys.stderr)
        return 1
    print("perf trajectory gate: clean "
          f"({len(_rows(load(args.fresh)))} rows vs baseline)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
