"""CacheService — the serving-path facade over the tiered store.

Replaces bare ``SemanticCache`` in front of the LLM engine.  The host
half owns response strings (a dict keyed by value id, garbage-collected
from the eviction reports every device op returns) and the per-tenant
policy table; the device half is `tiers`: a hot exact store, a warm IVF
ring, and a single jitted cascaded lookup.

Lifecycle of an entry:

  insert (admitted miss) -> hot tier -> [cold] demotion flush -> warm
  ring -> [ring wraps or tenant evicted] -> value id reported back ->
  host frees the response string.

The hot tier flushes its ``flush_size`` coldest rows to the warm ring
whenever occupancy crosses ``flush_watermark``; every
``rebuild_every``-th flush re-clusters the warm IVF (jittable k-means).
Between rebuilds the warm lookup scans a fixed tail window sized to
cover everything appended since the last rebuild, so recall does not
dip while the index is stale.

Drop-in surface: ``lookup(embs) / insert(embs, responses)`` match
``SemanticCache``; the tenant-aware surface adds ``tenant=`` (scalar or
per-row array) and ``scores=`` (admission) keywords.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache_service import tiers
from repro.cache_service.policy import PolicyTable, TenantPolicy
from repro.core.calibration import Calibration

TenantArg = Union[int, Sequence[int], np.ndarray]


class CacheService:
    supports_tenants = True

    def __init__(self, dim: int, *, hot_capacity: int = 1024,
                 warm_capacity: int = 16384, n_clusters: int = 64,
                 bucket: int = 256, n_probe: int = 8, topk: int = 1,
                 threshold: float = 0.85, admission_margin: float = 0.0,
                 flush_watermark: float = 0.85,
                 flush_size: Optional[int] = None, rebuild_every: int = 1,
                 kmeans_iters: int = 4, seed: int = 0,
                 fused: bool = False):
        """Build the tiered service.

        Tail invariant (see ``tiers.warm_query``): rows demoted into the
        warm ring stay unindexed until the next IVF rebuild and are only
        reachable through the brute-force tail window over the last
        ``tail`` ring writes.  The window is sized
        ``tail = flush_size * rebuild_every`` so that every row
        appended between rebuilds is covered — that product therefore
        must not exceed ``warm_capacity``.  When it does, the window is
        clamped to ``warm_capacity`` and ``_do_flush`` forces rebuilds
        earlier than ``rebuild_every`` would suggest (correct, but the
        configured cadence is unattainable); a warning is emitted at
        construction instead of silently accepting the config.

        ``fused=True`` routes the cascade through the fused Pallas
        lookup kernel (`kernels/cascade_lookup`) on TPU — subject to
        the kernel's VMEM budget: the warm slice must fit on-chip
        (DESIGN.md §3.1).  On CPU the flag falls back to the same
        four-op math, so it never changes results or CPU latency.
        """
        if flush_size is None:
            flush_size = max(hot_capacity // 4, 1)
        flush_size = min(flush_size, hot_capacity, warm_capacity)
        rebuild_every = max(rebuild_every, 1)
        # every row appended since the last rebuild lies in this window
        if flush_size * rebuild_every > warm_capacity:
            warnings.warn(
                f"tail window flush_size*rebuild_every ("
                f"{flush_size}*{rebuild_every}="
                f"{flush_size * rebuild_every}) exceeds warm_capacity "
                f"{warm_capacity}; clamping to warm_capacity and forcing "
                "IVF rebuilds before the unindexed backlog outgrows the "
                "window (the configured rebuild cadence will not be "
                "honored)", stacklevel=2)
        tail = min(flush_size * rebuild_every, warm_capacity)

        self.dim = dim
        self.hot_capacity = hot_capacity
        self.warm_capacity = warm_capacity
        self.flush_size = flush_size
        self.flush_watermark = flush_watermark
        self.rebuild_every = rebuild_every
        self.topk = topk

        self.hot = tiers.init_hot(hot_capacity, dim)
        self.warm = tiers.init_warm(warm_capacity, dim, n_clusters, bucket)
        self.policies = PolicyTable(TenantPolicy(threshold, admission_margin))
        self.responses: Dict[int, str] = {}
        self._next_vid = 0
        self._tail = tail
        self._n_probe = n_probe
        self.stats = {"lookups": 0, "hot_hits": 0, "warm_hits": 0,
                      "inserts": 0, "admission_skips": 0, "demotions": 0,
                      "rebuilds": 0, "evictions": 0}

        self.set_fused(fused)
        self._insert = jax.jit(tiers.hot_insert_batch)
        self._touch = jax.jit(tiers.hot_touch)
        self._demote = jax.jit(partial(tiers.demote_coldest, m=flush_size))
        self._append = jax.jit(tiers.warm_append)
        self._rebuild = jax.jit(partial(tiers.warm_rebuild, iters=kmeans_iters,
                                        seed=seed))
        self._evict_tenant = jax.jit(tiers.evict_tenant)

    def set_fused(self, fused: bool) -> None:
        """Select the cascade execution path (four-op vs fused kernel);
        re-jits the lookup, so flipping it mid-serve costs one trace."""
        self.fused = bool(fused)
        self._lookup = jax.jit(partial(
            tiers.cascade_query, k=self.topk, n_probe=self._n_probe,
            tail=self._tail, fused=self.fused))

    # ------------------------------------------------------------------
    # tenant policy surface
    # ------------------------------------------------------------------
    def set_tenant_policy(self, tenant: int, threshold: float,
                          admission_margin: float = 0.0) -> None:
        self.policies.set(tenant, TenantPolicy(threshold, admission_margin))

    def calibrate_tenant(self, tenant: int, scores, labels,
                         max_false_hit_rate: float = 0.01) -> Calibration:
        """Set this tenant's threshold from its own eval pairs under a
        false-hit budget."""
        return self.policies.calibrate(tenant, scores, labels,
                                       max_false_hit_rate)

    # ------------------------------------------------------------------
    # serving surface
    # ------------------------------------------------------------------
    def _tenant_row(self, tenant: TenantArg, n: int) -> np.ndarray:
        t = np.asarray(tenant, np.int32)
        if t.ndim == 0:
            t = np.full(n, int(t), np.int32)
        assert t.shape == (n,), (t.shape, n)
        return t

    def lookup(self, embs, tenant: TenantArg = 0
               ) -> Tuple[np.ndarray, np.ndarray, List[Optional[str]]]:
        """embs: (B, D).  Returns (hit (B,) bool, score (B,), values)."""
        embs = jnp.asarray(embs)
        qt = self._tenant_row(tenant, embs.shape[0])
        thr = self.policies.thresholds_for(qt)
        res = self._lookup(self.hot, self.warm, embs, jnp.asarray(qt),
                           jnp.asarray(thr))
        self.hot = self._touch(self.hot, res.hot_slots, res.hot_hit)
        hit = np.asarray(res.hit)
        scores = np.asarray(res.scores[:, 0])
        vids = np.asarray(res.value_ids[:, 0])
        hot_hit = np.asarray(res.hot_hit)
        self.stats["lookups"] += len(hit)
        self.stats["hot_hits"] += int(hot_hit.sum())
        self.stats["warm_hits"] += int((hit & ~hot_hit).sum())
        values = [self.responses.get(int(v)) if h else None
                  for h, v in zip(hit, vids)]
        return hit, scores, values

    def insert(self, embs, responses: Sequence[str], tenant: TenantArg = 0,
               scores: Optional[np.ndarray] = None) -> int:
        """Cache miss results.  ``scores`` (the best same-tenant score
        each query saw at lookup) enables the admission rule; without it
        every entry is admitted.  Returns the number admitted."""
        embs = np.asarray(embs)
        assert embs.shape[0] == len(responses)
        qt = self._tenant_row(tenant, len(responses))
        admit = self.policies.admit_mask(qt, scores)
        vids = np.full(len(responses), -1, np.int64)
        for i in np.nonzero(admit)[0]:
            vids[i] = self._next_vid
            self.responses[self._next_vid] = responses[i]
            self._next_vid += 1
        self.stats["inserts"] += int(admit.sum())
        self.stats["admission_skips"] += int((~admit).sum())
        self.hot, evicted = self._insert(
            self.hot, jnp.asarray(embs),
            jnp.asarray(vids, dtype=jnp.int32), jnp.asarray(qt))
        self._gc(evicted)
        self._maybe_flush()
        return int(admit.sum())

    def evict_tenant(self, tenant: int) -> int:
        """Drop every entry of one tenant from both tiers; frees the
        host strings.  Returns the number of entries evicted."""
        self.hot, self.warm, h_ev, w_ev = self._evict_tenant(
            self.hot, self.warm, jnp.asarray(tenant, jnp.int32))
        return self._gc(h_ev) + self._gc(w_ev)

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _gc(self, evicted) -> int:
        """Free response strings whose ids a device op reported evicted."""
        ids = np.asarray(evicted)
        n = 0
        for v in ids[ids >= 0]:
            if self.responses.pop(int(v), None) is not None:
                n += 1
        self.stats["evictions"] += n
        return n

    def _do_flush(self, rebuild: bool) -> None:
        self.hot, dem = self._demote(self.hot)
        self.warm, evicted = self._append(self.warm, dem)
        self._gc(evicted)
        self.stats["demotions"] += int(np.asarray(dem.mask).sum())
        # the tail window only covers the last `tail` ring writes; a
        # rebuild is forced before the unindexed backlog outgrows it,
        # else demoted rows would silently fall out of reach
        backlog = int(np.asarray(self.warm.total - self.warm.indexed_total))
        if rebuild or backlog + self.flush_size > self._tail:
            self.warm = self._rebuild(self.warm)
            self.stats["rebuilds"] += 1

    def _maybe_flush(self) -> None:
        n_valid = int(np.asarray(self.hot.valid).sum())
        if n_valid >= self.flush_watermark * self.hot_capacity:
            self._do_flush(rebuild=False)

    def flush(self, rebuild: bool = True) -> None:
        """Force one demotion flush now.  ``rebuild=False`` still
        rebuilds if skipping would leave rows beyond the tail window."""
        self._do_flush(rebuild)

    # ------------------------------------------------------------------
    @property
    def hot_occupancy(self) -> float:
        return float(np.asarray(self.hot.valid).mean())

    @property
    def warm_occupancy(self) -> float:
        return float(np.asarray(self.warm.valid).mean())

    @property
    def occupancy(self) -> float:
        """Drop-in parity with SemanticCache (fraction of total rows)."""
        n = int(np.asarray(self.hot.valid).sum()) \
            + int(np.asarray(self.warm.valid).sum())
        return n / (self.hot_capacity + self.warm_capacity)

    def __len__(self) -> int:
        return int(np.asarray(self.hot.valid).sum()) \
            + int(np.asarray(self.warm.valid).sum())
