"""Mamba (selective SSM) mixer — chunked associative-scan training path,
O(1)-state decode path.

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel is replaced
by a chunked formulation — an outer ``lax.scan`` over sequence chunks
carrying the SSM state h, with a ``lax.associative_scan`` inside each
chunk.  Working-set memory is O(chunk · d_inner · d_state) instead of
O(S · d_inner · d_state); the chunk size is the knob the §Perf loop can
turn.  The depthwise causal conv is a grouped `conv_general_dilated`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.param import Initializer

MAMBA_CHUNK = 256


def _dims(cfg: ModelConfig):
    s = cfg.ssm or SSMConfig()
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return s, d_in, dt_rank


def init_mamba(ini: Initializer, cfg: ModelConfig):
    s, d_in, R = _dims(cfg)
    N, K = s.d_state, s.d_conv
    d = cfg.d_model
    return {
        "in_proj": ini.lecun((d, 2 * d_in), ("embed", "mlp"), fan_in=d),
        "conv_w": ini.lecun((K, d_in), ("conv", "mlp"), fan_in=K),
        "conv_b": ini.zeros((d_in,), ("mlp",)),
        "x_proj": ini.lecun((d_in, R + 2 * N), ("mlp", "ssm"), fan_in=d_in),
        "dt_w": ini.lecun((R, d_in), ("ssm", "mlp"), fan_in=R),
        "dt_b": ini.constant((d_in,), ("mlp",), value=0.5),
        # A initialised to -[1..N] per channel (S4D-real init)
        "A_log": ini.constant((d_in, N), ("mlp", "ssm_state"), value=0.0),
        "D": ini.ones((d_in,), ("mlp",)),
        "out_proj": ini.lecun((d_in, d), ("mlp", "embed"), fan_in=d_in),
    }


def _causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C).  If ``state``
    ((B,K-1,C), the trailing inputs of the previous segment) is given it
    is prepended instead of zero padding.  Returns (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)               # (B, S+K-1, C)
    y = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NLC", "LIO", "NLC"),
        feature_group_count=C)
    new_state = xp[:, S:, :] if K > 1 else state
    return y + b.astype(x.dtype), new_state


def _ssm_inputs(p, cfg: ModelConfig, x_c):
    """x_c: (B,S,d_in) post-conv activations -> (A_bar, Bx, Cmat)."""
    s, d_in, R = _dims(cfg)
    N = s.d_state
    f32 = jnp.float32
    xdb = x_c.astype(f32) @ p["x_proj"].astype(f32)        # (B,S,R+2N)
    dt_raw, Bmat, Cmat = jnp.split(xdb, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_w"].astype(f32) + p["dt_b"].astype(f32))
    # S4D-real init: A = -exp(A_log) * [1..N]  (negative-definite; A_log=0
    # at init gives the canonical -[1..N] spectrum)
    A = -jnp.exp(p["A_log"].astype(f32)) * jnp.arange(1, N + 1, dtype=f32)[None, :]
    A_bar = jnp.exp(dt[..., None] * A)                     # (B,S,d_in,N)
    Bx = (dt * x_c.astype(f32))[..., None] * Bmat[..., None, :]
    return A_bar, Bx, Cmat


def _chunk_scan(A_bar, Bx, h0):
    """Within-chunk associative scan with incoming state h0.
    A_bar/Bx: (B,L,d_in,N); h0: (B,d_in,N) -> (h_all (B,L,d_in,N), h_last)."""

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    a_cum, b_cum = jax.lax.associative_scan(combine, (A_bar, Bx), axis=1)
    h_all = a_cum * h0[:, None] + b_cum
    return h_all, h_all[:, -1]


def apply_full(p, cfg: ModelConfig, x, *, return_state: bool = False):
    """x: (B,S,d).  Chunked scan over the sequence."""
    s, d_in, _ = _dims(cfg)
    N = s.d_state
    B, S, d = x.shape
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = _causal_conv(x_in, p["conv_w"], p["conv_b"])
    x_c = jax.nn.silu(x_conv)

    chunk = min(MAMBA_CHUNK, S)
    if cfg.unroll_inner:  # bound the unrolled loop at ~32 chunks
        chunk = max(chunk, -(-S // 32))
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    x_cp = jnp.pad(x_c, ((0, 0), (0, pad), (0, 0))) if pad else x_c

    A_bar, Bx, Cmat = _ssm_inputs(p, cfg, x_cp)
    Ab = A_bar.reshape(B, n_chunks, chunk, d_in, N).transpose(1, 0, 2, 3, 4)
    Bk = Bx.reshape(B, n_chunks, chunk, d_in, N).transpose(1, 0, 2, 3, 4)
    Ck = Cmat.reshape(B, n_chunks, chunk, N).transpose(1, 0, 2, 3)

    def body(h, xs):
        a, b, c = xs           # a,b: (B,chunk,d_in,N); c: (B,chunk,N)
        h_all, h_last = _chunk_scan(a, b, h)
        y = jnp.einsum("bldn,bln->bld", h_all, c)
        return h_last, y

    h0 = jnp.zeros((B, d_in, N), jnp.float32)
    if cfg.unroll_inner:
        ys_list = []
        h_last = h0
        for i in range(n_chunks):
            h_last, y_i = body(h_last, (Ab[i], Bk[i], Ck[i]))
            ys_list.append(y_i)
        ys = jnp.stack(ys_list)
    else:
        h_last, ys = jax.lax.scan(body, h0, (Ab, Bk, Ck))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, d_in)[:, :S]
    y = y + p["D"].astype(jnp.float32) * x_c.astype(jnp.float32)
    y = (y.astype(dt) * jax.nn.silu(z)) @ p["out_proj"].astype(dt)
    if return_state:
        return y, {"h": h_last, "conv": conv_state[:, -(s.d_conv - 1):, :]
                   if s.d_conv > 1 else conv_state}
    return y


def init_state(cfg: ModelConfig, batch: int, abstract: bool = False):
    s, d_in, _ = _dims(cfg)
    shapes = {
        "h": ((batch, d_in, s.d_state), jnp.dtype(jnp.float32)),
        "conv": ((batch, max(s.d_conv - 1, 1), d_in), jnp.dtype(cfg.dtype)),
    }
    if abstract:
        return {n: jax.ShapeDtypeStruct(sh, d) for n, (sh, d) in shapes.items()}
    return {n: jnp.zeros(sh, d) for n, (sh, d) in shapes.items()}


def state_axes():
    return {"h": ("batch", "mlp", "ssm_state"), "conv": ("batch", "conv", "mlp")}


def apply_prefill(p, cfg: ModelConfig, x):
    return apply_full(p, cfg, x, return_state=True)


def apply_decode(p, cfg: ModelConfig, x, state):
    """One token.  x: (B,1,d) -> (y, new_state)."""
    s, d_in, _ = _dims(cfg)
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    x_in, z = jnp.split(xz, 2, axis=-1)                     # (B,1,d_in)
    # conv over [state ; x]
    x_conv, new_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"],
                                    state=state["conv"].astype(dt))
    x_c = jax.nn.silu(x_conv)                               # (B,1,d_in)
    A_bar, Bx, Cmat = _ssm_inputs(p, cfg, x_c)
    h = A_bar[:, 0] * state["h"] + Bx[:, 0]                 # (B,d_in,N)
    y = jnp.einsum("bdn,bn->bd", h, Cmat[:, 0])[:, None]
    y = y + p["D"].astype(jnp.float32) * x_c.astype(jnp.float32)
    y = (y.astype(dt) * jax.nn.silu(z)) @ p["out_proj"].astype(dt)
    return y, {"h": h, "conv": new_conv}
