"""Distributed launch layer: production mesh, logical-axis sharding
rules, the multi-pod dry-run, roofline extraction, and the train/serve
launchers.  NOTE: do not import repro.launch.dryrun from library code —
it sets XLA_FLAGS at import time by design."""
from repro.launch.mesh import (
    HBM_BANDWIDTH, ICI_LINK_BANDWIDTH, PEAK_FLOPS_BF16, make_host_mesh,
    make_production_mesh,
)
from repro.launch.sharding import (
    RULE_SETS, SERVE_RULES, TRAIN_RULES, resolve_pspec, sharded_bytes,
    sharding_tree,
)

__all__ = [
    "HBM_BANDWIDTH", "ICI_LINK_BANDWIDTH", "PEAK_FLOPS_BF16",
    "make_host_mesh", "make_production_mesh", "RULE_SETS", "SERVE_RULES",
    "TRAIN_RULES", "resolve_pspec", "sharded_bytes", "sharding_tree",
]
