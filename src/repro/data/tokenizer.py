"""Deterministic hash-vocabulary tokenizer.

No trained vocabulary is available offline, so words map to stable ids
via FNV-1a hashing into the configured vocab (ids 0..3 reserved).  This
preserves the properties the cache pipeline needs: deterministic,
injective-enough (collisions ~ T/vocab), domain-independent, and
reproducible across processes (no Python ``hash`` randomisation).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

PAD, BOS, EOS, UNK = 0, 1, 2, 3
_RESERVED = 4
_WORD_RE = re.compile(r"[a-z0-9]+|[^\sa-z0-9]", re.IGNORECASE)


def _fnv1a(word: str) -> int:
    h = 0xCBF29CE484222325
    for b in word.encode("utf-8"):
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


@dataclass(frozen=True)
class HashTokenizer:
    vocab_size: int = 50368
    lowercase: bool = True

    def token_id(self, word: str) -> int:
        if self.lowercase:
            word = word.lower()
        return _RESERVED + _fnv1a(word) % (self.vocab_size - _RESERVED)

    def encode(self, text: str, max_len: int = 64, add_special: bool = True):
        """-> (ids (max_len,) int32, mask (max_len,) bool)."""
        words = _WORD_RE.findall(text)
        ids = [self.token_id(w) for w in words]
        if add_special:
            ids = [BOS] + ids[: max_len - 2] + [EOS]
        else:
            ids = ids[:max_len]
        n = len(ids)
        out = np.full(max_len, PAD, np.int32)
        out[:n] = ids[:max_len]
        mask = np.zeros(max_len, bool)
        mask[: min(n, max_len)] = True
        return out, mask

    def encode_batch(self, texts, max_len: int = 64):
        """-> (ids (B, max_len) int32, mask (B, max_len) bool)."""
        ids = np.zeros((len(texts), max_len), np.int32)
        mask = np.zeros((len(texts), max_len), bool)
        for i, t in enumerate(texts):
            ids[i], mask[i] = self.encode(t, max_len)
        return ids, mask
