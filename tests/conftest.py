"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — tests and
benches must see the single real CPU device; only launch/dryrun.py
forces the 512-device placeholder fleet."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _x64_off():
    # keep default f32 semantics everywhere
    yield


# ---------------------------------------------------------------------------
# plan/commit one-liners (the v2.0-removed lookup/insert shims, inlined
# as test helpers — tests that only exercise tier mechanics keep their
# two-call shape without resurrecting the deprecated surface)
# ---------------------------------------------------------------------------

def plan_lookup(svc, embs, tenant=0):
    """(hit, scores, responses) via one uncoalesced plan()."""
    from repro.cache_service.protocol import CacheRequest
    plan = svc.plan(CacheRequest.build(np.asarray(embs), tenant),
                    coalesce=False)
    return plan.hit, plan.scores, plan.responses


def commit_insert(svc, embs, responses, tenant=0, scores=None):
    """Commit a batch as admitted misses; returns the number admitted.
    ``scores`` (best same-tenant score at lookup) enables the
    admission rule, as the old insert shim did."""
    from repro.cache_service.protocol import CachePlan, CacheRequest
    embs = np.asarray(embs)
    assert embs.shape[0] == len(responses)
    req = CacheRequest.build(embs, tenant)
    admit = svc.policies.admit_mask(req.tenants, scores)
    plan = CachePlan.for_insert(req, admit, scores, epoch=svc._epoch,
                                embed_version=svc._embed_version)
    return svc.commit(plan, list(responses)).admitted
