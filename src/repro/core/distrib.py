"""Shared distributed top-k schedule: local candidates, tiny merge.

Every sharded lookup in this repo — the flat store
(`store.query_sharded`) and the sharded warm tier of the tiered cache
(`cache_service.tiers.cascade_query` with a mesh, DESIGN.md §8) — uses
the same two-step schedule: each shard computes a LOCAL top-k over its
corpus slice, then a tiny all-gather moves only the (Q, k) candidate
panels and a final top-k merges them.  The collective is
O(Q · k · shards) instead of GSPMD's O(Q · N) score-matrix gather.

This module is that merge, written once:

  * `merge_local_topk`   — the collective form, called inside
    `shard_map` (or any context with a named mesh axis);
  * `merge_stacked_topk` — the single-device oracle over shard-stacked
    (S, Q, k) candidates, bit-exact with the collective form because
    `all_gather(tiled=True, axis=1)` concatenates shard blocks in
    shard-major order — exactly what the stacked reshape produces.

Tie-breaking follows `lax.top_k` (lowest concatenated index wins), so
ties resolve to the earliest shard, then to the earlier candidate
within a shard — the property the sharded cascade relies on to keep
hot-tier candidates (shard 0, position 0) winning ties.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def merge_local_topk(axis: str, k: int, scores: jax.Array,
                     *payloads: jax.Array) -> Tuple[jax.Array, ...]:
    """Merge per-shard (Q, k) candidates into the global top-k.

    Must run under a named mesh axis (`shard_map`).  ``scores`` and
    every payload are the shard's local candidates, column-aligned;
    each is all-gathered along ``axis`` into shard-major (Q, k·S)
    panels and the global top-k is selected once on the scores.

    Returns ``(merged_scores, *merged_payloads)``, each (Q, k),
    replicated across the axis (all_gather leaves identical copies).
    """
    s_all = jax.lax.all_gather(scores, axis, axis=1, tiled=True)
    p_all = [jax.lax.all_gather(p, axis, axis=1, tiled=True)
             for p in payloads]
    sm, im = jax.lax.top_k(s_all, k)
    rows = jnp.arange(s_all.shape[0])[:, None]
    return (sm,) + tuple(p[rows, im] for p in p_all)


def merge_stacked_topk(k: int, scores: jax.Array,
                       *payloads: jax.Array) -> Tuple[jax.Array, ...]:
    """Single-device oracle of `merge_local_topk`.

    ``scores``/payloads are shard-stacked (S, Q, k); the concatenation
    order (shard-major, candidate-minor) matches the tiled all-gather,
    so both forms pick identical winners, ties included.
    """
    def flat(x):                                   # (S, Q, k) -> (Q, S*k)
        return jnp.moveaxis(x, 0, 1).reshape(x.shape[1], -1)

    s_all = flat(scores)
    sm, im = jax.lax.top_k(s_all, k)
    rows = jnp.arange(s_all.shape[0])[:, None]
    return (sm,) + tuple(flat(p)[rows, im] for p in payloads)
