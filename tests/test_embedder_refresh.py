"""Online embedder refresh with versioned hot-swap re-embed (§11).

Covers the full lifecycle (pair pooling -> trigger -> background train
-> eval gate -> shadow re-embed -> atomic publish / rollback) plus the
two §11 safety arguments:

  * **no resurrection**: a tenant evicted while the refresh thread is
    re-embedding its snapshot must stay evicted through the publish —
    the key-panel swap never touches ``valid``/``value_ids``;
  * **version consistency**: a plan embedded under version N commits
    against a version-N+1 service with its admissions *rejected* (and
    counted), never silently admitted into the wrong embedding space,
    while entries committed before the swap keep serving at recall 1.0
    because the panel was re-embedded into the space the live embed
    closure now produces.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

import repro.cache_service.service as service_mod
from repro.cache_service import CacheService, EmbedderRefreshPolicy, tiers
from repro.cache_service.protocol import CacheRequest
from repro.configs import get_config
from repro.core import EmbedderTrainer, FinetuneConfig
from repro.data import HashTokenizer


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


@pytest.fixture(scope="module")
def enc_setup():
    cfg = get_config("modernbert-149m").reduced(vocab_size=1024)
    tok = HashTokenizer(vocab_size=cfg.vocab_size)
    return cfg, tok


# a gate that always passes (unless eval-starved) + fast synth backfill
PERMISSIVE = dict(min_pairs=8, min_class=2, refresh_interval=8,
                  min_precision=0.0, min_recall=0.0,
                  max_f1_regression=10.0, synth_domain="medical",
                  synth_min_pairs=32)


def _service(enc_setup, **pol_kw):
    cfg, tok = enc_setup
    trainer = EmbedderTrainer(cfg, FinetuneConfig(
        epochs=1, batch_size=8, max_len=12))
    kw = dict(PERMISSIVE)
    kw.update(pol_kw)
    # threshold 0.9: the untrained embedder scores distinct template
    # texts up to ~0.87 against each other — only exact repeats (cosine
    # 1.0) may hit, so the stream below yields both hit and miss pairs
    svc = CacheService(dim=cfg.d_model, hot_capacity=64, warm_capacity=256,
                       n_clusters=4, bucket=32, threshold=0.9,
                       embedder_trainer=trainer, embedder_tokenizer=tok,
                       refresh_policy=EmbedderRefreshPolicy(**kw))
    return svc, trainer, trainer.make_embed_fn(tok)


def _drive(svc, emb, texts, tenant=0):
    plan = svc.plan(CacheRequest.build(emb(texts), tenant, texts=texts),
                    coalesce=False)
    resp = [None if h else f"r({t})" for h, t in zip(plan.hit, texts)]
    return plan, svc.commit(plan, resp)


def _stream(svc, emb, n=24, tenant=0, prefix="drug"):
    """Mixed stream: repeats (-> hits, positive pairs) + fresh queries
    (-> misses with a same-tenant neighbour, negative pairs)."""
    texts = [f"what dose of {prefix} {i % 6} should the patient take"
             for i in range(n)]
    for i in range(0, n, 4):
        _drive(svc, emb, texts[i:i + 4], tenant)
    return texts


# ---------------------------------------------------------------------------
# ctor / capability surface
# ---------------------------------------------------------------------------

def test_ctor_validation_and_caps(enc_setup):
    svc, _, _ = _service(enc_setup)
    caps = svc.capabilities()
    assert caps.learned_embedder and not caps.learned_admission
    with pytest.raises(ValueError):
        CacheService(dim=16, learned_embedder=True)


# ---------------------------------------------------------------------------
# tiers-level: the atomic key-panel swap primitive
# ---------------------------------------------------------------------------

def test_publish_reembedded_keys_swaps_only_keys():
    rng = np.random.default_rng(3)
    D, Nh, Nw = 16, 8, 32
    hot = tiers.init_hot(Nh, D)._replace(
        keys=jnp.asarray(_unit(rng.standard_normal((Nh, D))), jnp.float32),
        valid=jnp.asarray(rng.random(Nh) > 0.4),
        value_ids=jnp.asarray(rng.integers(0, 99, Nh), jnp.int32))
    warm = tiers.init_warm(Nw, D, 4, 8)._replace(
        keys=jnp.asarray(_unit(rng.standard_normal((Nw, D))), jnp.float32),
        valid=jnp.asarray(rng.random(Nw) > 0.4),
        value_ids=jnp.asarray(rng.integers(100, 199, Nw), jnp.int32),
        cursor=jnp.asarray(7, jnp.int32), total=jnp.asarray(19, jnp.int32))
    nh = rng.standard_normal((Nh, D)).astype(np.float32) * 3.0
    nw = rng.standard_normal((Nw, D)).astype(np.float32) * 3.0
    h2, w2 = tiers.publish_reembedded_keys(hot, warm, jnp.asarray(nh),
                                           jnp.asarray(nw))
    # keys swapped in re-normalized; int8 shadow requantized to match
    np.testing.assert_allclose(np.asarray(h2.keys), _unit(nh), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w2.keys), _unit(nw), atol=1e-6)
    q8, sc = tiers.quantize_rows(jnp.asarray(_unit(nw)))
    np.testing.assert_array_equal(np.asarray(w2.keys_q), np.asarray(q8))
    np.testing.assert_allclose(np.asarray(w2.scales), np.asarray(sc),
                               atol=1e-7)
    # liveness, identity and ring position are untouchable by a re-embed
    for a, b in [(hot.valid, h2.valid), (hot.value_ids, h2.value_ids),
                 (warm.valid, w2.valid), (warm.value_ids, w2.value_ids),
                 (warm.cursor, w2.cursor), (warm.total, w2.total),
                 (warm.centroids, w2.centroids)]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# trigger + synth backfill
# ---------------------------------------------------------------------------

def test_trigger_min_pairs_guard(enc_setup):
    svc, _, emb = _service(enc_setup, min_pairs=10**6)
    assert not svc._refresh_due()            # empty pool
    _stream(svc, emb, n=16)
    assert len(svc.feedback.pairs) > 0       # the stream did pool pairs
    assert not svc._refresh_due()            # but never enough


def test_trigger_min_class_guard_and_synth_waiver(enc_setup):
    # a hits-only stream pools positives exclusively: without a synth
    # domain the class guard must block the trigger forever
    svc, _, emb = _service(enc_setup, min_pairs=4, min_class=2,
                           synth_domain=None)
    for _ in range(6):
        _drive(svc, emb, ["repeat me exactly", "repeat me exactly also"])
    pairs = svc.feedback.pairs
    assert pairs.n_pos >= 4 and pairs.n_neg == 0
    assert not svc._refresh_due()
    # the same pool with a synth domain: backfill waives the guard
    svc._refresh_policy = EmbedderRefreshPolicy(**PERMISSIVE)
    assert svc._refresh_due()


def test_synth_backfill_balances_and_is_deterministic():
    from repro.data.corpora import PairDataset
    pol = EmbedderRefreshPolicy(**PERMISSIVE)
    one_class = PairDataset(q1=["a", "b"], q2=["c", "d"],
                            labels=np.ones(2, np.int32), domain="feedback")
    empty = PairDataset(q1=[], q2=[], labels=np.zeros(0, np.int32),
                        domain="feedback")
    tr, ev = service_mod._synth_backfill(one_class, empty, pol)
    assert len(tr.labels) + len(ev.labels) >= pol.synth_min_pairs
    assert len(set(np.asarray(tr.labels).tolist())) == 2   # balanced now
    assert len(set(np.asarray(ev.labels).tolist())) == 2
    assert list(tr.q1[:2]) == ["a", "b"]                   # originals kept
    tr2, ev2 = service_mod._synth_backfill(one_class, empty, pol)
    assert list(tr.q1) == list(tr2.q1) and list(ev.q2) == list(ev2.q2)
    np.testing.assert_array_equal(tr.labels, tr2.labels)
    # a balanced eval slice is left untouched (gate stays serving-only)
    balanced = PairDataset(q1=["a", "b"], q2=["c", "d"],
                           labels=np.asarray([0, 1], np.int32),
                           domain="feedback")
    _, ev3 = service_mod._synth_backfill(one_class, balanced, pol)
    assert list(ev3.q1) == ["a", "b"]


# ---------------------------------------------------------------------------
# full lifecycle: publish, hot swap, recall through the overlap
# ---------------------------------------------------------------------------

def test_refresh_publishes_and_recall_survives(enc_setup):
    svc, trainer, emb = _service(enc_setup)
    texts = _stream(svc, emb, n=24)
    assert svc._refresh_due()
    rep = svc.maintenance()
    assert rep.refresh_started and rep.refresh_in_flight
    old_hot_keys = np.asarray(svc.hot.keys).copy()
    rep = svc.maintenance(block=True)
    assert rep.refresh_published and not rep.refresh_rolled_back
    assert rep.embed_version == 1 and svc._embed_version == 1
    st = svc.stats_snapshot().refresh
    assert st["refreshes_published"] == 1 and st["embed_version"] == 1
    assert not st["refresh_in_flight"] and st["last_refresh_s"] > 0
    # the panel actually moved: valid hot rows were re-embedded
    valid = np.asarray(svc.hot.valid)
    assert valid.any()
    assert not np.allclose(np.asarray(svc.hot.keys)[valid],
                           old_hot_keys[valid])
    # recall 1.0 on committed entries THROUGH the swap: the live embed
    # closure reads the refreshed params and the panel was re-embedded
    # into the same space, so every previously-committed query (cosine
    # 1.0 against its own stored key) still hits
    uniq = sorted(set(texts))
    plan = svc.plan(CacheRequest.build(emb(uniq), 0, texts=uniq),
                    coalesce=False)
    assert plan.hit.all(), plan.scores
    assert all(r is not None for r in plan.responses)
    assert plan.embed_version == 1
    # receipts stamp the live version
    _, rc = _drive(svc, emb, ["a brand new post-swap query"])
    assert rc.embed_version == 1 and rc.stale_version_skipped == 0


def test_rollback_keeps_live_embedder_and_panel(enc_setup):
    svc, trainer, emb = _service(enc_setup, min_precision=1.01)
    _stream(svc, emb, n=24)
    keys_before = np.asarray(svc.hot.keys).copy()
    old_params = trainer.params
    assert svc.maintenance().refresh_started
    rep = svc.maintenance(block=True)
    assert rep.refresh_rolled_back and not rep.refresh_published
    assert svc._embed_version == 0
    assert trainer.params is old_params              # never touched
    np.testing.assert_array_equal(np.asarray(svc.hot.keys), keys_before)
    st = svc.stats_snapshot().refresh
    assert st["refreshes_rolled_back"] == 1
    assert st["refreshes_started"] == 1


def test_eval_starved_fails_closed(enc_setup):
    """No synth domain + a one-class eval slice: the gate must refuse
    to judge and roll back rather than publish unjudged."""
    svc, _, emb = _service(enc_setup, synth_domain=None, min_class=0,
                           min_pairs=4)
    for _ in range(4):                    # hits only -> all-positive pool
        _drive(svc, emb, ["repeat me exactly", "repeat me exactly also"])
    assert svc.feedback.pairs.n_neg == 0 and svc._refresh_due()
    svc.maintenance()
    rep = svc.maintenance(block=True)
    assert rep.refresh_rolled_back and svc._embed_version == 0


# ---------------------------------------------------------------------------
# version consistency: stale plans rejected at commit, not mis-scored
# ---------------------------------------------------------------------------

def test_stale_version_plan_rejected_at_commit(enc_setup):
    svc, _, emb = _service(enc_setup)
    _stream(svc, emb, n=24)
    stale_texts = ["an in-flight query planned under version zero"]
    stale_plan = svc.plan(CacheRequest.build(emb(stale_texts), 0,
                                             texts=stale_texts),
                          coalesce=False)
    assert stale_plan.embed_version == 0 and stale_plan.admit.any()
    svc.maintenance()
    svc.maintenance(block=True)           # publish: version -> 1
    assert svc._embed_version == 1
    live = len(svc.responses)
    rc = svc.commit(stale_plan, ["stale response"])
    assert rc.admitted == 0
    assert rc.stale_version_skipped == 1
    assert rc.embed_version == 1
    assert len(svc.responses) == live     # nothing entered the store
    assert svc.stats_snapshot().refresh["stale_version_commits"] == 1
    # the same query replanned under the live version commits fine
    plan2, rc2 = _drive(svc, emb, stale_texts)
    assert plan2.embed_version == 1
    assert rc2.stale_version_skipped == 0 and rc2.admitted == 1


# ---------------------------------------------------------------------------
# satellite: evict-tenant during the shadow re-embed (no resurrection)
# ---------------------------------------------------------------------------

def test_evict_during_shadow_reembed_no_resurrection(enc_setup,
                                                     monkeypatch):
    svc, _, emb = _service(enc_setup)
    _stream(svc, emb, n=16, tenant=0)
    doomed = _stream(svc, emb, n=8, tenant=1, prefix="other drug")
    assert svc._refresh_due()

    gate = threading.Event()
    real = service_mod._reembed_snapshot

    def gated(*a, **kw):
        assert gate.wait(timeout=120), "test gate never opened"
        return real(*a, **kw)

    # the refresh thread resolves the name at call time, so patching
    # the module global parks it right before the snapshot re-embed
    monkeypatch.setattr(service_mod, "_reembed_snapshot", gated)
    assert svc.maintenance().refresh_started

    # mid-flight: drop tenant 1 entirely (its vids are in the snapshot)
    t1_mask = np.asarray(svc.hot.tenants) == 1
    freed = set(np.asarray(svc.hot.value_ids)[
        t1_mask & np.asarray(svc.hot.valid)].tolist())
    assert freed
    assert svc.evict_tenant(1) >= len(freed)
    assert not (np.asarray(svc.hot.valid)
                & (np.asarray(svc.hot.tenants) == 1)).any()

    gate.set()
    rep = svc.maintenance(block=True)
    assert rep.refresh_published and svc._embed_version == 1

    # no resurrection: the freed rows stayed invalid through the swap
    live = {int(v) for v in svc._live_vids()}
    assert not (live & freed)
    dt = sorted(set(doomed))
    plan = svc.plan(CacheRequest.build(emb(dt), 1, texts=dt),
                    coalesce=False)
    assert not plan.hit.any()
    assert all(r is None for r in plan.responses)
    # and the surviving tenant still serves at full recall
    t0 = sorted({f"what dose of drug {i % 6} should the patient take"
                 for i in range(16)})
    plan0 = svc.plan(CacheRequest.build(emb(t0), 0, texts=t0),
                     coalesce=False)
    assert plan0.hit.all()


# ---------------------------------------------------------------------------
# publish-time threshold recalibration (§11)
# ---------------------------------------------------------------------------

def test_policy_table_recalibrate_all_moves_every_tenant():
    from repro.cache_service.policy import PolicyTable, TenantPolicy
    table = PolicyTable(TenantPolicy(0.9, 0.02))
    table.set(5, TenantPolicy(0.95, 0.01))
    table.recalibrate_all(0.8)
    assert table.default.threshold == 0.8
    assert table.get(5).threshold == 0.8
    assert table.get(7).threshold == 0.8          # unknown -> default
    # margins rescaled through with_threshold, not carried verbatim
    assert table.default.admission_margin == pytest.approx(
        TenantPolicy(0.9, 0.02).with_threshold(0.8).admission_margin)
    assert table.get(5).admission_margin == pytest.approx(
        TenantPolicy(0.95, 0.01).with_threshold(0.8).admission_margin)


def test_publish_recalibrates_thresholds_and_resets_scores(enc_setup):
    svc, _, emb = _service(enc_setup, recalibrate=True)
    svc.set_tenant_policy(9, threshold=0.95, admission_margin=0.01)
    _stream(svc, emb, n=24)
    assert svc.feedback._res                      # §9 reservoirs fed
    svc.maintenance()
    rep = svc.maintenance(block=True)
    assert rep.refresh_published
    new_thr = svc.policies.get(0).threshold
    lo, hi = svc._refresh_policy.recalibrate_bounds
    assert lo <= new_thr <= hi
    assert svc.policies.get(9).threshold == new_thr  # every tenant moved
    st = svc.stats_snapshot().refresh
    assert st["recalibrated_threshold"] == pytest.approx(new_thr)
    # old-space score reservoirs dropped; version-free pair texts kept
    assert not svc.feedback._res
    assert len(svc.feedback.pairs) > 0


def test_publish_without_recalibrate_keeps_thresholds(enc_setup):
    svc, _, emb = _service(enc_setup)             # recalibrate defaults off
    _stream(svc, emb, n=24)
    svc.maintenance()
    assert svc.maintenance(block=True).refresh_published
    assert svc.policies.get(0).threshold == 0.9
    assert svc.stats_snapshot().refresh["recalibrated_threshold"] is None


def test_rollback_never_recalibrates(enc_setup):
    svc, _, emb = _service(enc_setup, recalibrate=True, min_precision=1.01)
    _stream(svc, emb, n=24)
    assert svc.feedback._res
    svc.maintenance()
    assert svc.maintenance(block=True).refresh_rolled_back
    assert svc.policies.get(0).threshold == 0.9   # untouched
    assert svc.feedback._res                      # reservoirs survive
    assert svc.stats_snapshot().refresh["recalibrated_threshold"] is None


def test_texts_gc_with_responses(enc_setup):
    """Retained query texts are freed with the entry (no host leak)."""
    svc, _, emb = _service(enc_setup)
    _stream(svc, emb, n=16, tenant=3, prefix="leaky")
    assert svc._texts
    svc.evict_tenant(3)
    assert not svc._texts
