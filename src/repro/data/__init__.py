from repro.data.tokenizer import HashTokenizer, PAD, BOS, EOS, UNK
from repro.data.corpora import (
    DOMAINS, PairDataset, Query, make_pair_dataset, make_query_stream,
    render_query, sample_query,
)
from repro.data.pairs import iter_batches, shard_batch, tokenize_pairs

__all__ = [
    "HashTokenizer", "PAD", "BOS", "EOS", "UNK",
    "DOMAINS", "PairDataset", "Query", "make_pair_dataset",
    "make_query_stream", "render_query", "sample_query",
    "iter_batches", "shard_batch", "tokenize_pairs",
]
