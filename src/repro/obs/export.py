"""Exporters for registry snapshots (DESIGN.md §10.1).

Two render targets from the same ``MetricsRegistry.snapshot()`` dict:

  * **JSON-lines** (``to_jsonl`` / ``write_jsonl``): first line is a
    meta record (``{"schema": "repro.obs/v1", "kind": "meta", ...}``),
    then one line per series.  Line-oriented so a long-running server
    can append a snapshot per ``--metrics-interval`` and the file
    stays greppable/tailable.  ``read_jsonl`` parses a file back into
    ``(meta, series_list)``; ``validate_lines`` checks the documented
    schema and is what the CI metrics-smoke step runs.
  * **Prometheus text** (``to_prometheus``): classic exposition
    format — ``# HELP``/``# TYPE`` then one sample per series, with
    ``_bucket``/``_sum``/``_count`` expansion for histograms.

Run ``PYTHONPATH=src python -m repro.obs.export --validate FILE`` to
lint an emitted metrics file (exit 1 with reasons on mismatch).
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Tuple

from .registry import SCHEMA, MetricsRegistry

_KINDS = ("counter", "gauge", "histogram")


# ---------------------------------------------------------------------------
# JSON-lines
# ---------------------------------------------------------------------------

def to_jsonl(snapshot: Dict[str, object],
             meta: Optional[Dict[str, object]] = None) -> str:
    """Render one snapshot as JSON-lines (meta line first)."""
    head = {"schema": snapshot.get("schema", SCHEMA), "kind": "meta"}
    if meta:
        head.update(meta)
    lines = [json.dumps(head, sort_keys=True)]
    for name, m in sorted(snapshot.get("metrics", {}).items()):
        for s in m["series"]:
            rec = {"kind": m["kind"], "name": name, "labels": s["labels"]}
            if m["kind"] == "histogram":
                rec.update(count=s["count"], sum=s["sum"], le=s["le"],
                           buckets=s["buckets"], min=s["min"], max=s["max"])
            else:
                rec["value"] = s["value"]
            lines.append(json.dumps(rec, sort_keys=True))
    return "\n".join(lines) + "\n"


def write_jsonl(path: str, snapshot: Dict[str, object],
                meta: Optional[Dict[str, object]] = None,
                append: bool = False) -> None:
    with open(path, "a" if append else "w") as f:
        f.write(to_jsonl(snapshot, meta))


def read_jsonl(path: str) -> Tuple[List[Dict], List[Dict]]:
    """Parse a metrics file back: (meta records, series records)."""
    metas, series = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            (metas if rec.get("kind") == "meta" else series).append(rec)
    return metas, series


def validate_lines(lines: Iterable[str]) -> List[str]:
    """Check JSON-lines output against the documented schema
    (DESIGN.md §10.1).  Returns a list of problems; empty = valid."""
    problems: List[str] = []
    saw_meta = False
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"line {i}: not JSON ({e})")
            continue
        if not isinstance(rec, dict):
            problems.append(f"line {i}: not an object")
            continue
        kind = rec.get("kind")
        if kind == "meta":
            if i == 1:
                saw_meta = True
            if rec.get("schema") != SCHEMA:
                problems.append(
                    f"line {i}: meta schema {rec.get('schema')!r} != "
                    f"{SCHEMA!r}")
            continue
        if kind not in _KINDS:
            problems.append(f"line {i}: unknown kind {kind!r}")
            continue
        if not isinstance(rec.get("name"), str) or not rec["name"]:
            problems.append(f"line {i}: missing metric name")
        if not isinstance(rec.get("labels"), dict):
            problems.append(f"line {i}: labels must be an object")
        if kind == "histogram":
            le, buckets = rec.get("le"), rec.get("buckets")
            if not isinstance(le, list) or not isinstance(buckets, list) \
                    or len(buckets) != len(le) + 1:
                problems.append(
                    f"line {i}: histogram needs len(buckets) == len(le)+1")
            elif sum(buckets) != rec.get("count"):
                problems.append(
                    f"line {i}: bucket counts {sum(buckets)} != count "
                    f"{rec.get('count')}")
            if not isinstance(rec.get("sum"), (int, float)):
                problems.append(f"line {i}: histogram missing sum")
        else:
            if not isinstance(rec.get("value"), (int, float)):
                problems.append(f"line {i}: {kind} missing numeric value")
    if not saw_meta:
        problems.append("line 1: first line must be the meta record")
    return problems


def validate_file(path: str) -> List[str]:
    with open(path) as f:
        return validate_lines(f)


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _fmt_labels(labels: Dict[str, str], extra: Tuple = ()) -> str:
    items = list(labels.items()) + list(extra)
    if not items:
        return ""
    body = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in items)
    return "{" + body + "}"


def to_prometheus(snapshot: Dict[str, object]) -> str:
    """Classic Prometheus text format from a snapshot dict."""
    out: List[str] = []
    for name, m in sorted(snapshot.get("metrics", {}).items()):
        if m.get("help"):
            out.append(f"# HELP {name} {m['help']}")
        out.append(f"# TYPE {name} {m['kind']}")
        for s in m["series"]:
            lab = s["labels"]
            if m["kind"] == "histogram":
                acc = 0
                for bound, c in zip(s["le"], s["buckets"]):
                    acc += c
                    out.append(f"{name}_bucket"
                               f"{_fmt_labels(lab, (('le', repr(bound)),))}"
                               f" {acc}")
                acc += s["buckets"][-1]
                out.append(f"{name}_bucket"
                           f"{_fmt_labels(lab, (('le', '+Inf'),))} {acc}")
                out.append(f"{name}_sum{_fmt_labels(lab)} {s['sum']}")
                out.append(f"{name}_count{_fmt_labels(lab)} {s['count']}")
            else:
                out.append(f"{name}{_fmt_labels(lab)} {s['value']}")
    return "\n".join(out) + "\n"


def render(registry: MetricsRegistry, fmt: str = "jsonl",
           meta: Optional[Dict[str, object]] = None) -> str:
    snap = registry.snapshot()
    if fmt == "jsonl":
        return to_jsonl(snap, meta)
    if fmt in ("prom", "prometheus"):
        return to_prometheus(snap)
    raise ValueError(f"unknown format {fmt!r}")


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="validate or convert repro.obs metrics files")
    ap.add_argument("path", help="JSON-lines metrics file")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the file (exit 1 on problems)")
    ap.add_argument("--prom", action="store_true",
                    help="print the file re-rendered as Prometheus text")
    args = ap.parse_args(argv)
    problems = validate_file(args.path)
    if args.validate:
        for p in problems:
            print(f"FAIL {args.path}: {p}")
        if not problems:
            metas, series = read_jsonl(args.path)
            print(f"OK {args.path}: {len(metas)} snapshot(s), "
                  f"{len(series)} series")
        return 1 if problems else 0
    if args.prom:
        metas, series = read_jsonl(args.path)
        snap: Dict[str, object] = {"schema": SCHEMA, "metrics": {}}
        for rec in series:
            m = snap["metrics"].setdefault(
                rec["name"], {"kind": rec["kind"], "help": "",
                              "label_names": sorted(rec["labels"]),
                              "series": []})
            s = {"labels": rec["labels"]}
            if rec["kind"] == "histogram":
                s.update(count=rec["count"], sum=rec["sum"], le=rec["le"],
                         buckets=rec["buckets"], min=rec.get("min", 0),
                         max=rec.get("max", 0))
            else:
                s["value"] = rec["value"]
            m["series"].append(s)
        print(to_prometheus(snap), end="")
        return 0
    ap.error("pick one of --validate / --prom")
    return 2


if __name__ == "__main__":
    raise SystemExit(_main())
