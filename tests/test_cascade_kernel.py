"""Fused cascade-lookup kernel: interpret-mode parity with the four-op
cascade (exact score/index agreement across tenants, tail rows and
invalid slots), plus fused/unfused agreement through a real demotion
flush + rebuild cycle."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import commit_insert, plan_lookup

from repro.cache_service import CacheService, tiers
from repro.core import ivf as ivf_lib
from repro.kernels.cascade_lookup import kernel as cl_kernel
from repro.kernels.cascade_lookup import ops as cl_ops
from repro.kernels.cascade_lookup import ref as cl_ref

rng = np.random.default_rng(7)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _random_states(Nh=50, Nw=128, D=16, K=8, bucket=16, n_tenants=3,
                   unindexed=20):
    """Semantically arbitrary but shape-consistent tier arrays: random
    invalid slots, mixed tenants, a stale-index window of `unindexed`
    rows written after the last rebuild."""
    hk = jnp.asarray(_unit(rng.standard_normal((Nh, D)).astype(np.float32)))
    hv = jnp.asarray(rng.random(Nh) > 0.3)
    ht = jnp.asarray(rng.integers(0, n_tenants, Nh), jnp.int32)
    hvid = jnp.asarray(rng.integers(0, 1000, Nh), jnp.int32)
    hot = tiers.init_hot(Nh, D)._replace(keys=hk, valid=hv, tenants=ht,
                                         value_ids=hvid)

    wk = jnp.asarray(_unit(rng.standard_normal((Nw, D)).astype(np.float32)))
    wv = jnp.asarray(rng.random(Nw) > 0.2)
    wt = jnp.asarray(rng.integers(0, n_tenants, Nw), jnp.int32)
    wvid = jnp.asarray(rng.integers(1000, 2000, Nw), jnp.int32)
    wseq = jnp.asarray(rng.permutation(Nw) + 1, jnp.int32)
    cent = ivf_lib.kmeans(wk, wv, K, 4, 0)
    members, sizes = ivf_lib.build_lists(wk, wv, cent, bucket)
    warm = tiers.init_warm(Nw, D, K, bucket)._replace(
        keys=wk, valid=wv, tenants=wt, value_ids=wvid, write_seq=wseq,
        cursor=jnp.asarray(int(rng.integers(0, Nw)), jnp.int32),
        total=jnp.asarray(Nw, jnp.int32), centroids=cent, members=members,
        sizes=sizes, indexed_total=jnp.asarray(Nw - unindexed, jnp.int32))
    return hot, warm


def _queries(n_q, D, n_tenants=3):
    q = jnp.asarray(_unit(rng.standard_normal((n_q, D)).astype(np.float32)))
    qt = jnp.asarray(rng.integers(0, n_tenants, n_q), jnp.int32)
    thr = jnp.asarray(rng.uniform(0.2, 0.9, n_q).astype(np.float32))
    return q, qt, thr


def _flatten(hot, warm):
    return (hot.keys, hot.valid, hot.tenants, hot.value_ids,
            warm.keys, warm.valid, warm.tenants, warm.value_ids,
            warm.write_seq, warm.centroids, warm.members, warm.cursor,
            warm.indexed_total)


# ---------------------------------------------------------------------------
# array-level kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,n_probe,tail,block_n", [
    (1, 2, 0, 64),      # no tail, single hot block
    (1, 4, 10, 16),     # tail window + multi-block hot stream
    (3, 4, 10, 16),     # k > 1
    (2, 8, 5, 32),      # n_probe clamped to n_clusters
])
def test_fused_kernel_matches_oracle(k, n_probe, tail, block_n):
    hot, warm = _random_states()
    q, qt, thr = _queries(9, 16)
    args = (q, qt, thr) + _flatten(hot, warm)
    ref = cl_ref.cascade_lookup(*args, k=k, n_probe=n_probe, tail=tail)
    ker = cl_kernel.cascade_lookup(*args, k=k, n_probe=n_probe, tail=tail,
                                   block_n=block_n, interpret=True)
    for name, a, b in zip(("scores", "value_ids", "warm_slots", "hot_slots",
                           "hot_hit", "hit"), ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


@pytest.mark.parametrize("n_wblocks", [1, 2, 8])
@pytest.mark.parametrize("quantized", [False, True])
def test_blockwise_warm_stream_matches_oracle(n_wblocks, quantized):
    """DESIGN.md §12: the warm panel streams through the Pallas grid in
    blocks, so a warm slice larger than the single-block VMEM design
    size still runs — and every block count is bit-exact with the
    four-op oracle (whose panel is gathered whole), fp32 and int8,
    including ring wraparound of the tail window."""
    hot, warm = _random_states(Nw=256, unindexed=30)
    if quantized:
        warm = tiers.requantize(warm)
    q, qt, thr = _queries(9, 16)
    args = (q, qt, thr) + _flatten(hot, warm)
    kw = dict(k=3, n_probe=4, tail=16)
    qkw = dict(warm_keys_q=warm.keys_q, warm_scales=warm.scales,
               quantized=True) if quantized else {}
    ref = cl_ref.cascade_lookup(*args, **kw, **qkw)
    ker = cl_kernel.cascade_lookup(*args, **kw, **qkw, block_n=16,
                                   warm_block_n=256 // n_wblocks,
                                   interpret=True)
    for name, a, b in zip(("scores", "value_ids", "warm_slots", "hot_slots",
                           "hot_hit", "hit"), ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_blockwise_warm_stream_ragged_last_block():
    """Warm capacity not divisible by warm_block_n: the padded rows of
    the streamed panel must stay dead weight (no candidate can reach
    them), so results still match the oracle bit-for-bit."""
    hot, warm = _random_states(Nw=200, unindexed=25)
    q, qt, thr = _queries(7, 16)
    args = (q, qt, thr) + _flatten(hot, warm)
    ref = cl_ref.cascade_lookup(*args, k=2, n_probe=4, tail=12)
    ker = cl_kernel.cascade_lookup(*args, k=2, n_probe=4, tail=12,
                                   block_n=32, warm_block_n=64,
                                   interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cascade_query_warm_block_n_matches_default():
    """tiers-level: cascade_query(warm_block_n=...) on the kernel path
    equals the unfused four-op result."""
    hot, warm = _random_states(Nw=128)
    q, qt, thr = _queries(8, 16)
    base = tiers.cascade_query(hot, warm, q, qt, thr, k=2, n_probe=4,
                               tail=8, fused=False)
    blk = tiers.cascade_query(hot, warm, q, qt, thr, k=2, n_probe=4,
                              tail=8, fused=True, use_kernel=True,
                              warm_block_n=32)
    _assert_same_result(base, blk)


def test_fused_kernel_empty_warm_tier():
    """Fresh service: centroids are zero, every inverted list is empty —
    the kernel must mask all IVF candidates, not fabricate hits."""
    hot, _ = _random_states()
    warm = tiers.init_warm(64, 16, 4, 8)
    q, qt, thr = _queries(5, 16)
    args = (q, qt, thr) + _flatten(hot, warm)
    ref = cl_ref.cascade_lookup(*args, k=2, n_probe=4, tail=4)
    ker = cl_kernel.cascade_lookup(*args, k=2, n_probe=4, tail=4,
                                   block_n=32, interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_kernel_all_invalid_never_hits():
    hot = tiers.init_hot(32, 16)
    warm = tiers.init_warm(64, 16, 4, 8)
    q, qt, _ = _queries(4, 16)
    thr = jnp.full((4,), 0.0, jnp.float32)
    s, vids, _, _, hot_hit, hit = cl_kernel.cascade_lookup(
        q, qt, thr, *_flatten(hot, warm), k=1, n_probe=2, tail=4,
        block_n=32, interpret=True)
    assert float(jnp.max(s)) < -1e20
    assert not bool(jnp.any(hit)) and not bool(jnp.any(hot_hit))
    assert int(jnp.max(vids)) == -1


def test_ops_dispatch_paths_agree():
    """ops-level: forced kernel (interpret) and forced oracle agree."""
    hot, warm = _random_states()
    q, qt, thr = _queries(6, 16)
    args = (q, qt, thr) + _flatten(hot, warm)
    a = cl_ops.cascade_lookup(*args, k=2, n_probe=4, tail=6,
                              use_kernel=False)
    b = cl_ops.cascade_lookup(*args, k=2, n_probe=4, tail=6,
                              use_kernel=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# tiers-level: fused flag on the cascade
# ---------------------------------------------------------------------------

def _assert_same_result(a, b):
    for name in tiers.CascadeResult._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


def test_cascade_query_fused_matches_unfused_after_flush_rebuild():
    """Drive a real service through demotion flushes + an IVF rebuild
    cycle, then compare cascade_query(fused=True) — kernel forced —
    against fused=False on the resulting tier states."""
    d = 16
    svc = CacheService(dim=d, hot_capacity=32, warm_capacity=128,
                       n_clusters=4, bucket=32, n_probe=4, threshold=0.8,
                       flush_size=8, rebuild_every=2)
    for step in range(10):
        e = _unit(rng.standard_normal((8, d)).astype(np.float32))
        commit_insert(svc, e, [f"s{step}-{i}" for i in range(8)],
                      tenant=step % 3)
    st = svc.stats_snapshot()
    assert st.tiers["demotions"] > 0 and st.rebuild["rebuilds"] > 0
    # the warm ring now holds indexed rows AND a post-rebuild tail
    assert int(svc.warm.total - svc.warm.indexed_total) > 0

    q, qt, thr = _queries(16, d)
    for k, tail in [(1, svc._tail), (2, svc._tail), (1, 0)]:
        unfused = tiers.cascade_query(svc.hot, svc.warm, q, qt, thr, k=k,
                                      n_probe=4, tail=tail, fused=False)
        fused = tiers.cascade_query(svc.hot, svc.warm, q, qt, thr, k=k,
                                    n_probe=4, tail=tail, fused=True,
                                    use_kernel=True)
        _assert_same_result(unfused, fused)


def test_service_fused_flag_serves_identically():
    """Two services fed the same trace, one fused: every lookup must
    agree (hits, scores, served strings)."""
    d = 24
    mk = lambda fused: CacheService(
        dim=d, hot_capacity=16, warm_capacity=64, n_clusters=4, bucket=32,
        n_probe=4, threshold=0.85, flush_size=8, rebuild_every=1,
        fused=fused)
    a, b = mk(False), mk(True)
    assert not a.fused and b.fused
    for step in range(8):
        e = _unit(rng.standard_normal((8, d)).astype(np.float32))
        texts = [f"s{step}-{i}" for i in range(8)]
        commit_insert(a, e, texts, tenant=step % 2)
        commit_insert(b, e, texts, tenant=step % 2)
        for t in range(2):
            ha, sa, va = plan_lookup(a, e, tenant=t)
            hb, sb, vb = plan_lookup(b, e, tenant=t)
            np.testing.assert_array_equal(ha, hb)
            np.testing.assert_allclose(sa, sb)
            assert va == vb


def test_tail_invariant_warning_on_unsafe_config():
    """flush_size * rebuild_every > warm_capacity clamps the tail window
    and must warn instead of silently degrading the rebuild cadence."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        CacheService(dim=8, hot_capacity=64, warm_capacity=32,
                     n_clusters=2, bucket=16, flush_size=32,
                     rebuild_every=4)
    assert any("tail window" in str(x.message) for x in w)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        CacheService(dim=8, hot_capacity=64, warm_capacity=256,
                     n_clusters=2, bucket=16, flush_size=32,
                     rebuild_every=4)
    assert not [x for x in w if "tail window" in str(x.message)]
