"""Golden parity: the coalesced plan/commit pipeline is bit-exact with
the naive two-call serving loop (uncoalesced per-batch plan, then a
fresh for_insert commit of the misses — the v2.0-removed lookup/insert
shims, inlined) — hits, scores, value ids, admissions, evictions and
the full device tier state — for both backends (SemanticCache and
CacheService) and both cascade paths (fused and unfused).  The query
mix includes exact in-batch duplicates, so miss coalescing is exercised
while keeping even the host strings identical."""
import numpy as np
import pytest
from conftest import commit_insert, plan_lookup

from repro.cache_service import CachePlan, CacheRequest, CacheService
from repro.core import SemanticCache

rng = np.random.default_rng(29)


def _unit(x):
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _batches(d, n_batches=8, batch=8, repeat_frac=0.4):
    """Query stream with cross-batch repeats and exact in-batch dups."""
    seen = []
    out = []
    for b in range(n_batches):
        rows = []
        for i in range(batch - 1):
            if seen and rng.random() < repeat_frac:
                rows.append(seen[rng.integers(len(seen))])
            else:
                e = _unit(rng.standard_normal(d).astype(np.float32))
                seen.append(e)
                rows.append(e)
        rows.append(rows[0])        # exact duplicate within the batch
        out.append(np.stack(rows))
    return out


def _two_call_serve(cache, embs, tenant, tenant_aware):
    """The naive serving loop: one uncoalesced read plan, generate
    every miss, commit them through a fresh for_insert plan with the
    observed scores (exactly what the removed lookup/insert shims
    did)."""
    if tenant_aware:
        hits, scores, values = plan_lookup(cache, embs, tenant=tenant)
    else:
        plan = cache.plan(CacheRequest.build(np.asarray(embs)),
                          coalesce=False)
        hits, scores, values = plan.hit, plan.scores, plan.responses
    miss = [i for i, h in enumerate(hits) if not h]
    if miss:
        answers = [f"gen({embs[i].tobytes().hex()[:12]})" for i in miss]
        sel = np.asarray(miss)
        if tenant_aware:
            commit_insert(cache, embs[sel], answers, tenant=tenant,
                          scores=scores[sel])
        else:
            req = CacheRequest.build(np.asarray(embs[sel]))
            cache.commit(CachePlan.for_insert(
                req, np.ones(len(req), bool)), answers)
    return np.asarray(hits), np.asarray(scores), values


def _plan_commit_serve(cache, embs, tenant):
    """The typed pipeline: plan -> one generation per miss-group leader
    -> commit."""
    plan = cache.plan(CacheRequest.build(embs, tenant))
    responses = [None] * len(embs)
    for i in plan.miss_rows():
        lead = int(plan.miss_leader[i])
        responses[int(i)] = f"gen({embs[lead].tobytes().hex()[:12]})"
    cache.commit(plan, responses)
    return plan.hit, plan.scores, plan.responses


def _assert_tree_equal(a, b, names):
    for name, x, y in zip(names, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


def _parity_counts(svc):
    s = svc.stats_snapshot()
    return {"lookups": s.traffic["lookup_rows"],
            "hot_hits": s.traffic["hot_hits"],
            "warm_hits": s.traffic["warm_hits"],
            "inserts": s.admission["admitted"],
            "admission_skips": s.admission["skipped"],
            "demotions": s.tiers["demotions"],
            "rebuilds": s.rebuild["rebuilds"],
            "evictions": s.tiers["evictions"]}


@pytest.mark.parametrize("fused", [False, True])
def test_cache_service_plan_commit_matches_two_call_loop(fused):
    d = 24
    mk = lambda: CacheService(
        dim=d, hot_capacity=16, warm_capacity=64, n_clusters=4, bucket=32,
        n_probe=4, threshold=0.85, admission_margin=0.05, flush_size=8,
        rebuild_every=2, fused=fused)
    naive, typed = mk(), mk()
    for b, embs in enumerate(_batches(d)):
        tenant = b % 3
        lh, ls, lv = _two_call_serve(naive, embs, tenant,
                                     tenant_aware=True)
        th, ts, tv = _plan_commit_serve(typed, embs, tenant)
        np.testing.assert_array_equal(lh, th, err_msg=f"batch {b} hits")
        np.testing.assert_array_equal(ls, ts, err_msg=f"batch {b} scores")
        assert lv == tv, f"batch {b} hit responses"
        # full device-state parity after every batch: same admissions,
        # same value-id assignment, same demotions/evictions
        _assert_tree_equal(naive.hot, typed.hot,
                           [f"hot.{f}" for f in naive.hot._fields])
        _assert_tree_equal(naive.warm, typed.warm,
                           [f"warm.{f}" for f in naive.warm._fields])
        assert naive.responses == typed.responses, f"batch {b}"
    assert _parity_counts(naive) == _parity_counts(typed)


def test_semantic_cache_plan_commit_matches_two_call_loop():
    d = 24
    naive = SemanticCache(capacity=64, dim=d, threshold=0.85)
    typed = SemanticCache(capacity=64, dim=d, threshold=0.85)
    for b, embs in enumerate(_batches(d)):
        lh, ls, lv = _two_call_serve(naive, embs, 0, tenant_aware=False)
        th, ts, tv = _plan_commit_serve(typed, embs, 0)
        np.testing.assert_array_equal(lh, th, err_msg=f"batch {b} hits")
        np.testing.assert_array_equal(ls, ts, err_msg=f"batch {b} scores")
        assert lv == tv
        _assert_tree_equal(naive.state, typed.state,
                           [f"state.{f}" for f in naive.state._fields])
        assert naive.responses == typed.responses
    assert naive.stats_snapshot()["inserts"] \
        == typed.stats_snapshot()["inserts"]


def test_for_insert_plan_applies_admission_like_serve_path():
    """Committing through a for_insert plan (the helper the removed
    insert shim compiled down to) must admit exactly the rows the
    policy's admission mask selects, and leave identical device
    state to an explicit for_insert commit."""
    d = 16
    a = CacheService(dim=d, hot_capacity=16, warm_capacity=32, n_clusters=2,
                     bucket=16, threshold=0.9, admission_margin=0.1)
    b = CacheService(dim=d, hot_capacity=16, warm_capacity=32, n_clusters=2,
                     bucket=16, threshold=0.9, admission_margin=0.1)
    e = _unit(rng.standard_normal((6, d)).astype(np.float32))
    scores = np.asarray([0.0, 0.85, 0.3, 0.95, 0.5, 0.82], np.float32)
    n_a = commit_insert(a, e, [f"r{i}" for i in range(6)], tenant=1,
                        scores=scores)
    req = CacheRequest.build(e, 1)
    admit = b.policies.admit_mask(req.tenants, scores)
    n_b = b.commit(CachePlan.for_insert(req, admit, scores),
                   [f"r{i}" for i in range(6)]).admitted
    assert n_a == n_b == int(admit.sum()) < 6
    _assert_tree_equal(a.hot, b.hot, a.hot._fields)
    assert a.responses == b.responses
