from repro.configs.base import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    LayerSpec,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    get_config,
    list_configs,
    register,
    ATTN, MAMBA, SLSTM, MLSTM, DENSE, MOE, NONE,
)

__all__ = [
    "ASSIGNED_ARCHS", "INPUT_SHAPES", "LayerSpec", "ModelConfig",
    "MoEConfig", "ShapeConfig", "SSMConfig", "XLSTMConfig",
    "get_config", "list_configs", "register",
    "ATTN", "MAMBA", "SLSTM", "MLSTM", "DENSE", "MOE", "NONE",
]
